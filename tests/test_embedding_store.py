"""EmbeddingStore (PR 7): cached per-layer tables + dirty-frontier
incremental re-embedding, validated against full recompute.

Contract (ISSUE 7): after random feature updates and random edge
additions, ``refresh()`` re-embeds ONLY the forward-influence frontier
and the resulting tables equal a from-scratch store on the updated
graph (allclose — edge rebuilds may reorder CSR neighbor lists, which
permutes float summation order).  Boundaries: an empty update is a
0-row no-op; marking the whole graph dirty re-embeds every row and
still matches."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.embedding_store import EmbeddingStore


def _cfg(g, **kw):
    base = dict(name="es", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=8,
                n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                batch_size=32, loss="ce", use_agg_kernel=False,
                agg_interpret=True, agg_b_tile=4, agg_d_tile=8,
                agg_k_slab=2)
    base.update(kw)
    return GNNConfig(**base)


def _store(g, cfg, params, **kw):
    s = EmbeddingStore(params, cfg, g, chunk_size=48, **kw)
    s.build()
    return s


def _copy_graph(g):
    return dataclasses.replace(g, feats=g.feats.copy(),
                               indptr=g.indptr.copy(),
                               indices=g.indices.copy())


def _assert_matches_fresh(store, params, cfg, **tol):
    tol = tol or dict(rtol=1e-4, atol=1e-5)
    fresh = _store(store.graph, cfg, params)
    for li, (a, b) in enumerate(zip(store.layers, fresh.layers)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   err_msg=f"layer {li}", **tol)


@pytest.mark.parametrize("model,kernel", [("graphsage", False),
                                          ("gcn", False), ("gcn", True)])
def test_feature_update_incremental_equals_full(small_graph, model,
                                                kernel):
    g = _copy_graph(small_graph)
    cfg = _cfg(g, model=model, use_agg_kernel=kernel)
    params = G.init_gnn(jax.random.key(0), cfg, g.feats.shape[1])
    store = _store(g, cfg, params)
    rng = np.random.default_rng(1)
    nodes = rng.choice(g.n, size=6, replace=False)
    store.update_features(
        nodes, rng.normal(size=(6, g.feats.shape[1])).astype(np.float32))
    assert store.dirty
    info = store.refresh()
    assert not store.dirty
    # genuinely incremental: strictly fewer rows than a full rebuild,
    # and the frontier grows monotonically layer to layer
    assert info["rows_per_layer"][0] >= len(nodes)
    assert all(a <= b for a, b in zip(info["rows_per_layer"],
                                      info["rows_per_layer"][1:]))
    assert info["total_rows"] < g.n * cfg.n_layers
    _assert_matches_fresh(store, params, cfg)


def test_edge_update_incremental_equals_full(small_graph):
    g = _copy_graph(small_graph)
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(1), cfg, g.feats.shape[1])
    store = _store(g, cfg, params)
    rng = np.random.default_rng(2)
    src = rng.choice(g.n, size=5, replace=False)
    dst = rng.choice(g.n, size=5, replace=False)
    old_nnz = len(store.graph.indices)
    store.add_edges(src, dst)
    assert len(store.graph.indices) >= old_nnz   # self-loops dropped
    info = store.refresh()
    assert 0 < info["total_rows"] < g.n * cfg.n_layers
    _assert_matches_fresh(store, params, cfg)


def test_edge_update_affects_neighbor_weights(small_graph):
    """ã depends on BOTH endpoint degrees: adding one edge (u, v) must
    re-derive the ELL rows of u, v AND their existing neighbors."""
    g = _copy_graph(small_graph)
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(2), cfg, g.feats.shape[1])
    store = _store(g, cfg, params)
    u = int(np.argmax(g.degrees))                # has neighbors for sure
    v = int((u + g.n // 2) % g.n)
    if v in set(g.neighbors(u)) or v == u:
        v = (v + 1) % g.n
    nb = set(store.graph.neighbors(u))
    store.add_edges([u], [v])
    dirty = set(np.nonzero(store._dirty_row)[0])
    assert {u, v} <= dirty and nb <= dirty
    store.refresh()
    _assert_matches_fresh(store, params, cfg)


def test_empty_update_is_noop(small_graph):
    cfg = _cfg(small_graph)
    params = G.init_gnn(jax.random.key(3), cfg,
                        small_graph.feats.shape[1])
    store = _store(small_graph, cfg, params)
    before = [np.asarray(t) for t in store.layers]
    info = store.refresh()
    assert info["total_rows"] == 0
    assert info["rows_per_layer"] == [0] * cfg.n_layers
    for a, b in zip(store.layers, before):
        assert np.array_equal(np.asarray(a), b)
    # add_edges with only self-loops is also a no-op
    store.add_edges([1, 2], [1, 2])
    assert not store.dirty


def test_whole_graph_dirty_equals_rebuild(small_graph):
    g = small_graph
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(4), cfg, g.feats.shape[1])
    store = _store(g, cfg, params)
    store.mark_dirty(np.arange(g.n))
    info = store.refresh()
    assert info["rows_per_layer"] == [g.n] * cfg.n_layers
    _assert_matches_fresh(store, params, cfg)


def test_frontier_preview_matches_refresh(small_graph):
    cfg = _cfg(small_graph)
    params = G.init_gnn(jax.random.key(5), cfg,
                        small_graph.feats.shape[1])
    store = _store(small_graph, cfg, params)
    store.mark_dirty([0, 7])
    fronts = store.frontier()
    info = store.refresh()
    assert [int(f.sum()) for f in fronts] == info["rows_per_layer"]


def test_query_autorefresh_and_predict(small_graph):
    g = _copy_graph(small_graph)
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(6), cfg, g.feats.shape[1])
    store = _store(g, cfg, params)
    rng = np.random.default_rng(7)
    store.update_features([3], rng.normal(size=(1, g.feats.shape[1]))
                          .astype(np.float32))
    assert store.dirty
    preds = store.predict([0, 3, 11])            # triggers refresh
    assert not store.dirty
    fresh = _store(store.graph, cfg, params)
    want = np.argmax(np.asarray(fresh.layers[-1])[[0, 3, 11]], -1)
    assert np.array_equal(preds, want)
    logits = store.query_logits([5, 3])
    np.testing.assert_allclose(
        logits, np.asarray(store.layers[-1])[[5, 3]], rtol=1e-6)


def test_wal_pending_updates_and_staleness(small_graph):
    """PR 10: writers append to the WAL; ``pending_updates`` /
    ``staleness_s`` track what the serving snapshot does not reflect
    yet, and a successful refresh zeroes both."""
    g = _copy_graph(small_graph)
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(10), cfg, g.feats.shape[1])
    store = _store(g, cfg, params)
    assert store.version == 1
    assert store.pending_updates() == 0
    assert store.staleness_s() == 0.0
    rng = np.random.default_rng(10)
    store.update_features([1], rng.normal(size=(1, g.feats.shape[1]))
                          .astype(np.float32))
    store.mark_dirty([2])
    assert store.pending_updates() == 2
    assert store.staleness_s() > 0.0
    store.refresh()
    assert store.version == 2
    assert store.pending_updates() == 0
    assert store.staleness_s() == 0.0
    _assert_matches_fresh(store, params, cfg)


def test_predict_meta_serves_stale_without_refresh(small_graph):
    """``predict_meta`` answers from the current snapshot and reports
    its version + staleness; only ``predict``/``query_logits`` keep the
    PR-7 auto-refresh behavior."""
    g = _copy_graph(small_graph)
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(11), cfg, g.feats.shape[1])
    store = _store(g, cfg, params)
    before = np.argmax(store.snapshot().final_np, -1)
    rng = np.random.default_rng(11)
    store.update_features(np.arange(8),
                          rng.normal(size=(8, g.feats.shape[1]))
                          .astype(np.float32))
    preds, ver, stale = store.predict_meta(np.arange(g.n))
    assert ver == 1 and stale > 0.0
    assert np.array_equal(preds, before)     # old version, NOT refreshed
    assert store.dirty
    store.predict([0])                       # auto-refreshes
    assert not store.dirty
    assert store.predict_meta([0])[1] == 2


def test_capped_max_deg_store(small_graph):
    """A degree-capped store stays consistent with a capped fresh
    rebuild through updates (truncated ELL is the documented layout)."""
    g = _copy_graph(small_graph)
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(8), cfg, g.feats.shape[1])
    store = EmbeddingStore(params, cfg, g, chunk_size=48, max_deg=6)
    store.build()
    assert store.K == 6
    rng = np.random.default_rng(9)
    store.update_features([2, 4], rng.normal(size=(2, g.feats.shape[1]))
                          .astype(np.float32))
    store.refresh()
    fresh = EmbeddingStore(params, cfg, store.graph, chunk_size=48,
                           max_deg=6)
    fresh.build()
    for a, b in zip(store.layers, fresh.layers):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
