"""Chaos suite: deterministic fault injection against every recovery
path — supervised prefetch restarts, the engine's non-finite
BadStepPolicy (skip / raise / rollback, sync AND deferred), kill-mid-
checkpoint + exact resume, and crash-safe sweep journaling."""
import dataclasses
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core import faults
from repro.core.engine import (BadStepPolicy, Callback, FullGraphSource,
                               NonFiniteStepError, SampledSource, Trainer,
                               TrainPlan)
from repro.core.experiment import sweep
from repro.core.prefetch import Prefetcher


def _cfg(g, **kw):
    base = dict(name="chaos", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=16, n_classes=g.n_classes,
                n_layers=2, fanout=(4, 3), batch_size=32, loss="ce")
    base.update(kw)
    return GNNConfig(**base)


@pytest.fixture(autouse=True)
def _no_armed_failpoints():
    yield
    faults.disarm()


# ---------------------------------------------------------------------------
# Supervised Prefetcher
# ---------------------------------------------------------------------------

def _targets(graph, n=6, seed=0, **kw):
    """The target-node sequence a Prefetcher run delivers."""
    out = []
    pf = Prefetcher(graph, 16, (3,), seed=seed, n_batches=n, **kw)
    try:
        for fb, _ in pf:
            out.append(np.asarray(fb.nodes[0]))   # hop 0 = target nodes
    finally:
        pf.close()
    return out, pf


def test_transient_worker_fault_restart_preserves_sequence(small_graph):
    clean, _ = _targets(small_graph, n=6)
    from repro.core.sampler import sample_batch
    flaky_sample = faults.flaky(sample_batch, fail_at={2})
    with pytest.warns(RuntimeWarning, match="transient"):
        faulty, pf = _targets(small_graph, n=6, sample_fn=flaky_sample,
                              backoff=0.001)
    assert pf.restarts == 1
    assert len(faulty) == len(clean) == 6
    for a, b in zip(clean, faulty):     # batch 2 replayed, not skipped
        np.testing.assert_array_equal(a, b)


def test_restart_budget_exhaustion_escalates_to_fatal(small_graph):
    from repro.core.sampler import sample_batch
    flaky_sample = faults.flaky(sample_batch, fail_at=range(10))
    pf = Prefetcher(small_graph, 16, (3,), n_batches=4,
                    sample_fn=flaky_sample, max_restarts=2, backoff=0.001)
    try:
        with pytest.warns(RuntimeWarning, match="transient"):
            with pytest.raises(faults.TransientSamplerFault):
                for _ in range(4):
                    pf.next()
    finally:
        pf.close()


def test_fatal_worker_fault_surfaces_immediately(small_graph):
    from repro.core.sampler import sample_batch
    flaky_sample = faults.flaky(sample_batch, fail_at={1},
                                exc=faults.FatalSamplerFault)
    pf = Prefetcher(small_graph, 16, (3,), n_batches=4,
                    sample_fn=flaky_sample)
    try:
        pf.next()                        # batch 0 fine
        with pytest.raises(faults.FatalSamplerFault):
            for _ in range(3):
                pf.next()
        assert pf.restarts == 0          # fatal != transient
    finally:
        pf.close()


def test_next_after_sentinel_raises_immediately(small_graph):
    """Post-exhaustion next() must re-raise instantly, not deadlock on
    the drained queue (the pre-fault-tolerance bug)."""
    pf = Prefetcher(small_graph, 16, (3,), n_batches=2)
    try:
        pf.next(), pf.next()
        with pytest.raises(StopIteration):
            pf.next()
        outcome = {}

        def call_again():
            try:
                pf.next()
            except BaseException as e:
                outcome["exc"] = e

        t = threading.Thread(target=call_again, daemon=True)
        t0 = time.perf_counter()
        t.start()
        t.join(timeout=2.0)
        assert not t.is_alive(), "next() after sentinel deadlocked"
        assert isinstance(outcome["exc"], StopIteration)
        assert time.perf_counter() - t0 < 2.0
    finally:
        pf.close()


def test_fatal_error_rereaised_after_sentinel(small_graph):
    from repro.core.sampler import sample_batch
    flaky_sample = faults.flaky(sample_batch, fail_at={0},
                                exc=faults.FatalSamplerFault)
    pf = Prefetcher(small_graph, 16, (3,), n_batches=2,
                    sample_fn=flaky_sample)
    try:
        for _ in range(3):               # every call: same stored error
            with pytest.raises(faults.FatalSamplerFault):
                pf.next()
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# Non-finite step guard + BadStepPolicy
# ---------------------------------------------------------------------------

class _ParamTrace(Callback):
    """Copies params every step (donation-safe) keyed by iteration."""

    def __init__(self):
        self.at = {}

    def on_step(self, state):
        self.at[state.it] = jax.tree.map(jnp.copy, state.params)


def _params_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("deferred", [False, True],
                         ids=["sync", "deferred"])
def test_nan_step_skip_policy(small_graph, deferred):
    """NaN batch at step k: loss recorded as nan, bad step logged,
    params UNCHANGED across the bad step, training continues — under
    both sync and one-step-lagged deferred readback."""
    g = small_graph
    k = 3
    plan = TrainPlan(lr=0.3, n_iters=8, seed=0, eval_every=100,
                     deferred_sync=deferred,
                     bad_steps=BadStepPolicy(on_bad="skip",
                                             max_consecutive=4))
    src = faults.poison_batches(SampledSource(), at_iters=[k])
    trace = _ParamTrace()
    res = Trainer(g, _cfg(g), plan, source=src,
                  extra_callbacks=[trace]).run()
    assert len(res.history.losses) == 8
    assert np.isnan(res.history.losses[k])
    assert all(np.isfinite(l) for i, l in enumerate(res.history.losses)
               if i != k)
    assert res.history.bad_steps == [k + 1]          # 1-based
    # the guard made step k an identity update.  The trace records
    # state.params at record-consumption time, which under deferred
    # readback is already one step ahead of the record — shift by one.
    off = 1 if deferred else 0
    assert _params_equal(trace.at[k - off], trace.at[k - 1 - off])
    # and step k+1 moved again (resampled batch, finite grads)
    assert not _params_equal(trace.at[k + 1 - off], trace.at[k - off])


def test_nan_step_raise_policy_default(small_graph):
    g = small_graph
    plan = TrainPlan(lr=0.3, n_iters=6, seed=0, eval_every=100,
                     deferred_sync=False)       # default on_bad="raise"
    src = faults.poison_batches(SampledSource(), at_iters=[2])
    with pytest.raises(NonFiniteStepError, match="iteration 2"):
        Trainer(g, _cfg(g), plan, source=src).run()


def test_nan_streak_escalates_after_max_consecutive(small_graph):
    g = small_graph
    plan = TrainPlan(lr=0.3, n_iters=10, seed=0, eval_every=100,
                     deferred_sync=False,
                     bad_steps=BadStepPolicy(on_bad="skip",
                                             max_consecutive=2))
    src = faults.poison_batches(SampledSource(), at_iters=[3, 4, 5])
    with pytest.raises(NonFiniteStepError) as ei:
        Trainer(g, _cfg(g), plan, source=src).run()
    assert ei.value.consecutive == 2


def test_nan_streak_rollback_policy(small_graph, tmp_path):
    """k consecutive NaN steps with checkpointing on: the engine
    restores the last checkpoint and finishes with finite params."""
    g = small_graph
    # deterministic 2-step NaN streak somewhere in iters 4..9 (after the
    # first it=3 checkpoint exists) — same fault seed, same streak
    bad = {4 + i for i in faults.FaultSchedule(7).consecutive(n=6, k=2)}
    plan = TrainPlan(lr=0.3, n_iters=12, seed=0, eval_every=100,
                     ckpt_every=3, ckpt_dir=str(tmp_path),
                     bad_steps=BadStepPolicy(on_bad="rollback",
                                             max_consecutive=2))
    src = faults.poison_batches(SampledSource(), at_iters=sorted(bad))
    with pytest.warns(RuntimeWarning, match="rolling back"):
        res = Trainer(g, _cfg(g), plan, source=src).run()
    assert len(res.history.bad_steps) == 2
    assert len(res.history.losses) == 12
    assert all(np.isfinite(x) for x in
               jax.tree.leaves(jax.tree.map(jnp.sum, res.params)))


def test_rollback_policy_requires_checkpoints(small_graph):
    with pytest.raises(ValueError, match="ckpt_every"):
        Trainer(small_graph, _cfg(small_graph),
                TrainPlan(n_iters=4,
                          bad_steps=BadStepPolicy(on_bad="rollback")),
                source=FullGraphSource())


def test_bad_step_policy_validation():
    with pytest.raises(ValueError):
        BadStepPolicy(on_bad="explode")
    with pytest.raises(ValueError):
        BadStepPolicy(escalate="shrug")


# ---------------------------------------------------------------------------
# Kill mid-checkpoint during training -> exact resume
# ---------------------------------------------------------------------------

def test_kill_mid_checkpoint_then_resume_equals_uninterrupted(
        small_graph, tmp_path):
    g, cfg = small_graph, _cfg(small_graph)
    golden_dir = str(tmp_path / "golden")
    plan = TrainPlan(lr=0.3, n_iters=9, seed=0, eval_every=4,
                     ckpt_every=3, ckpt_dir=golden_dir)
    golden = Trainer(g, cfg, plan, source=SampledSource()).run()

    # run 2: SIGKILL stand-in mid-save of the it=6 checkpoint
    crash_dir = str(tmp_path / "crash")
    plan2 = dataclasses.replace(plan, ckpt_dir=crash_dir)
    with faults.armed("ckpt.before_npz_rename", at_hits=(1,)):
        with pytest.raises(faults.SimulatedCrash):
            Trainer(g, cfg, plan2, source=SampledSource()).run()
    from repro.checkpoint import latest_step
    assert latest_step(crash_dir) == 3      # it=6 save never completed

    res = Trainer(g, cfg, plan2, source=SampledSource()).run(
        resume_from=crash_dir)
    assert res.history.losses == golden.history.losses
    assert res.history.val_accs == golden.history.val_accs
    assert res.history.bad_steps == golden.history.bad_steps
    assert _params_equal(res.params, golden.params)
    assert res.final_test_acc == golden.final_test_acc


# ---------------------------------------------------------------------------
# Crash-safe sweeps
# ---------------------------------------------------------------------------

def _sweep_args(g):
    cfg = _cfg(g, n_layers=1, fanout=(3,))
    plan = TrainPlan(lr=0.3, n_iters=2, eval_every=100)
    return cfg, plan, dict(batch_sizes=[16, 32], fanout_grid=[(3,)])


def test_sweep_journal_resume_skips_completed(small_graph, tmp_path):
    g = small_graph
    cfg, plan, kw = _sweep_args(g)
    journal = str(tmp_path / "sweep.jsonl")
    with faults.armed("sweep.after_point", at_hits=(0,)):
        with pytest.raises(faults.SimulatedCrash):
            sweep(g, cfg, plan, journal=journal, **kw)
    lines = [json.loads(l) for l in open(journal)]
    assert [l["status"] for l in lines] == ["ok"]

    rows = sweep(g, cfg, plan, journal=journal, **kw)
    lines = [json.loads(l) for l in open(journal)]
    assert len(rows) == 2
    assert len(lines) == 2                  # point 1 NOT rerun
    assert rows[0] == lines[0]["row"]       # journaled row returned as-is


def test_sweep_isolates_point_failure_into_error_row(
        small_graph, tmp_path, monkeypatch):
    g = small_graph
    cfg, plan, kw = _sweep_args(g)
    journal = str(tmp_path / "sweep.jsonl")
    import repro.core.experiment as X
    real = X.run_experiment

    def exploding(graph, cfg_, plan_, **kwargs):
        if kwargs.get("b") == 16:
            raise RuntimeError("boom at b=16")
        return real(graph, cfg_, plan_, **kwargs)

    monkeypatch.setattr(X, "run_experiment", exploding)
    rows = sweep(g, cfg, plan, journal=journal, **kw)
    assert len(rows) == 2
    assert rows[0]["status"] == "error" and "boom" in rows[0]["error"]
    assert rows[1].get("status") != "error"
    # error points are RETRIED on resume (only ok rows are skipped)
    monkeypatch.setattr(X, "run_experiment", real)
    rows2 = sweep(g, cfg, plan, journal=journal, **kw)
    assert all(r.get("status") != "error" for r in rows2)


def test_sweep_without_journal_fails_fast(small_graph, monkeypatch):
    g = small_graph
    cfg, plan, kw = _sweep_args(g)
    import repro.core.experiment as X

    def exploding(*a, **k):
        raise RuntimeError("boom")

    monkeypatch.setattr(X, "run_experiment", exploding)
    with pytest.raises(RuntimeError, match="boom"):
        sweep(g, cfg, plan, **kw)


def test_sweep_degrades_pallas_kernel_failure(small_graph, monkeypatch):
    g = small_graph
    cfg, plan, kw = _sweep_args(g)
    cfg = dataclasses.replace(cfg, use_agg_kernel=True, agg_interpret=True)
    import repro.core.experiment as X
    real, seen = X.run_experiment, []

    def mosaic_fails(graph, cfg_, plan_, **kwargs):
        seen.append(cfg_.use_agg_kernel)
        if cfg_.use_agg_kernel:
            raise RuntimeError("Mosaic lowering failed: unsupported op")
        return real(graph, cfg_, plan_, **kwargs)

    monkeypatch.setattr(X, "run_experiment", mosaic_fails)
    with pytest.warns(RuntimeWarning, match="DEGRADING"):
        rows = sweep(g, cfg, plan, batch_sizes=[16], fanout_grid=[(3,)])
    assert seen == [True, False]           # kernel try, einsum retry
    assert all(r.get("agg_kernel_degraded") for r in rows)


# ---------------------------------------------------------------------------
# Determinism of the injection layer itself
# ---------------------------------------------------------------------------

def test_fault_schedule_deterministic():
    a, b = faults.FaultSchedule(11), faults.FaultSchedule(11)
    assert a.pick(100, 5) == b.pick(100, 5)
    assert a.consecutive(50, 4) == b.consecutive(50, 4)
    run = sorted(faults.FaultSchedule(3).consecutive(50, 4))
    assert len(run) == 4
    assert run == list(range(run[0], run[0] + 4))
