"""GNNServer (PR 7): request micro-batching, latency/throughput
counters, and answer correctness against the direct forward — plus the
experiment module's inference axis riding on the same stack."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.embedding_store import EmbeddingStore
from repro.core.graph import to_ell
from repro.core.serving import GNNServer


def _cfg(g, **kw):
    base = dict(name="srv", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=8,
                n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                batch_size=32, loss="ce")
    base.update(kw)
    return GNNConfig(**base)


@pytest.fixture(scope="module")
def served(small_graph):
    cfg = _cfg(small_graph)
    params = G.init_gnn(jax.random.key(0), cfg,
                        small_graph.feats.shape[1])
    store = EmbeddingStore(params, cfg, small_graph, chunk_size=64)
    store.build()
    idx, w, ws = to_ell(small_graph)
    logits = G.full_graph_forward(params, cfg,
                                  jnp.asarray(small_graph.feats),
                                  jnp.asarray(idx), jnp.asarray(w),
                                  jnp.asarray(ws))
    return store, params, cfg, np.argmax(np.asarray(logits), -1)


def test_answers_match_direct_forward(served):
    store, _, _, expect = served
    rng = np.random.default_rng(0)
    with GNNServer(store, max_batch=16, max_wait_ms=1.0) as server:
        for _ in range(5):
            q = rng.integers(0, store.graph.n, size=rng.integers(1, 12))
            assert np.array_equal(server.classify(q), expect[q])
        st = server.stats()
    assert st["n_requests"] == 5 and st["n_batches"] >= 1
    assert st["p99_ms"] >= st["p50_ms"] > 0.0
    assert st["qps"] > 0.0 and st["mean_batch_queries"] > 0.0


def test_microbatch_coalescing_deterministic(served):
    """``start=False`` queues requests before the batcher runs, so
    coalescing is deterministic: 10 one-node requests under max_batch=4
    are served in exactly ceil(10/4) = 3 batches."""
    store, _, _, expect = served
    server = GNNServer(store, max_batch=4, max_wait_ms=20.0, start=False)
    futs = [server.submit([i]) for i in range(10)]
    server.start()
    try:
        for i, f in enumerate(futs):
            assert f.result(timeout=30.0)[0] == expect[i]
        st = server.stats()
        assert st["n_requests"] == 10
        assert st["n_queries"] == 10
        assert st["n_batches"] == 3
    finally:
        server.close()


def test_max_batch_one_disables_coalescing(served):
    store, _, _, _ = served
    server = GNNServer(store, max_batch=1, max_wait_ms=20.0, start=False)
    futs = [server.submit([i]) for i in range(6)]
    server.start()
    try:
        for f in futs:
            f.result(timeout=30.0)
        assert server.stats()["n_batches"] == 6
    finally:
        server.close()


def test_max_wait_flushes_partial_batch(served):
    """A lone request must not wait for max_batch to fill — the
    max_wait_ms deadline flushes it."""
    store, _, _, expect = served
    with GNNServer(store, max_batch=1024, max_wait_ms=5.0) as server:
        t0 = time.perf_counter()
        out = server.classify([3], timeout=30.0)
        took = time.perf_counter() - t0
    assert out[0] == expect[3]
    assert took < 10.0       # flushed by deadline, not stuck


def test_serving_after_update_uses_incremental_refresh(small_graph):
    g = dataclasses.replace(small_graph, feats=small_graph.feats.copy())
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(1), cfg, g.feats.shape[1])
    store = EmbeddingStore(params, cfg, g, chunk_size=64)
    store.build()
    rng = np.random.default_rng(2)
    with GNNServer(store, max_batch=8, max_wait_ms=1.0) as server:
        server.classify([0, 1])
        store.update_features(
            [5], rng.normal(size=(1, g.feats.shape[1]))
            .astype(np.float32))
        q = rng.integers(0, g.n, size=16)
        got = server.classify(q)             # refreshes on the batcher
    assert not store.dirty
    idx, w, ws = to_ell(store.graph)
    logits = G.full_graph_forward(params, cfg,
                                  jnp.asarray(store.graph.feats),
                                  jnp.asarray(idx), jnp.asarray(w),
                                  jnp.asarray(ws))
    assert np.array_equal(got, np.argmax(np.asarray(logits), -1)[q])


def test_submit_after_close_raises(served):
    store, _, _, _ = served
    server = GNNServer(store, max_batch=4)
    server.classify([0])
    server.close()
    server.close()                            # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        server.submit([1])


def test_experiment_inference_axis(small_graph):
    """run_experiment(inference=True) appends the serving-cost columns,
    and the cached-embedding accuracy equals the trainer's own
    full-neighborhood test accuracy."""
    from repro.core.engine import TrainPlan
    from repro.core.experiment import run_experiment
    cfg = _cfg(small_graph, hidden=16)
    plan = TrainPlan(lr=0.3, n_iters=3, eval_every=2, seed=0)
    row = run_experiment(small_graph, cfg, plan, paradigm="minibatch",
                         b=32, fanouts=(4, 3), inference=True,
                         serve_queries=6)
    for key in ("inference_ms_per_node", "serve_p50_ms", "serve_p99_ms",
                "serve_qps", "serve_acc"):
        assert key in row, key
    assert row["inference_ms_per_node"] > 0
    assert row["serve_p99_ms"] >= row["serve_p50_ms"] > 0
    assert row["serve_acc"] == pytest.approx(row["test_acc"], abs=1e-6)
