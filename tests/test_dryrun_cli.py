"""The multi-pod dry-run CLI, end to end in a subprocess (it must own the
512-device XLA flag — tests keep 1 device)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("args,tag", [
    (["--arch", "mamba2-130m", "--shape", "decode_32k", "--single-pod"],
     "mamba2-130m__decode_32k__16x16"),
    (["--arch", "gnn-papers100m", "--shape", "minibatch_train",
      "--multi-pod"],
     "gnn-papers100m__minibatch_train__2x16x16"),
])
def test_dryrun_cli_compiles(tmp_path, args, tag):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args,
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open(tmp_path / f"{tag}.json"))
    assert rec["status"] == "ok", rec
    assert rec["per_device_flops"] > 0
    assert set(rec["roofline"]) >= {"compute_s", "memory_s",
                                    "collective_s", "dominant"}
    assert rec["memory"]["argument_size_in_bytes"] > 0
