"""Write-safe serving under chaos (PR 10).

The snapshot-consistency property: with writers streaming
``update_features``/``add_edges`` and failpoints armed at every new
serving/store failpoint, no query ever observes a torn or partially
refreshed table — every served prediction equals a full recompute at
SOME consistent snapshot version (a prefix of the applied update
sequence), and a server with ``max_staleness_s`` set never answers
from a snapshot older than the bound.

Runs in tier-1 AND under ``make chaos`` (Makefile wires this file into
the chaos target next to the checkpoint/resume crash tests)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core import faults
from repro.core import gnn as G
from repro.core.embedding_store import EmbeddingStore
from repro.core.graph import to_ell
from repro.core.serving import (DeadlineExceededError, GNNServer,
                                ServedAnswer, ServerOverloadedError,
                                ServeStats, _Reservoir)


@pytest.fixture(autouse=True)
def _no_armed_failpoints():
    yield
    faults.disarm()


@pytest.fixture(autouse=True)
def _quiet_thread_crashes(monkeypatch):
    """Injected SimulatedCrash kills daemon threads by design; keep the
    default excepthook traceback out of the test output."""
    monkeypatch.setattr(threading, "excepthook", lambda args: None)


def _cfg(g, **kw):
    base = dict(name="chaos-srv", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=8,
                n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                batch_size=32, loss="ce")
    base.update(kw)
    return GNNConfig(**base)


def _copy_graph(g):
    import dataclasses
    return dataclasses.replace(g, feats=g.feats.copy(),
                               indptr=g.indptr.copy(),
                               indices=g.indices.copy())


def _built(small_graph, key=0):
    g = _copy_graph(small_graph)
    cfg = _cfg(g)
    params = G.init_gnn(jax.random.key(key), cfg, g.feats.shape[1])
    store = EmbeddingStore(params, cfg, g, chunk_size=64)
    store.build()
    return store, params, cfg


def _forward_argmax(store, params, cfg, feats=None):
    idx, w, ws = to_ell(store.graph)
    logits = G.full_graph_forward(
        params, cfg,
        jnp.asarray(store.graph.feats if feats is None else feats),
        jnp.asarray(idx), jnp.asarray(w), jnp.asarray(ws))
    return np.argmax(np.asarray(logits), -1)


# ---------------------------------------------------------------------------
# versioned snapshots: crashes mid-refresh never tear the serving state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fp", ["store.mid_layer_refresh",
                                "store.before_swap"])
def test_crash_mid_refresh_keeps_old_snapshot(small_graph, fp):
    store, params, cfg = _built(small_graph, key=0)
    snap0 = store.snapshot()
    final0 = snap0.final_np.copy()
    rng = np.random.default_rng(0)
    store.update_features([3, 9], rng.normal(size=(2, 16))
                          .astype(np.float32))
    with faults.armed(fp):
        with pytest.raises(faults.SimulatedCrash):
            store.refresh()
    # partial version discarded: same snapshot object, same version,
    # byte-identical final table, dirty info intact
    assert store.snapshot() is snap0
    assert store.version == snap0.version
    np.testing.assert_array_equal(store.snapshot().final_np, final0)
    assert store.dirty
    # queries keep answering from the old consistent version
    preds, ver, _ = store.predict_meta(np.arange(store.graph.n))
    assert ver == snap0.version
    np.testing.assert_array_equal(preds, np.argmax(final0, -1))
    # the WAL/dirty masks were NOT lost: the retry catches up exactly
    store.refresh()
    assert store.version == snap0.version + 1 and not store.dirty
    np.testing.assert_array_equal(store.predict_meta([0])[0],
                                  _forward_argmax(store, params, cfg)[:1])


def test_snapshot_immutable_across_versions(small_graph):
    store, params, cfg = _built(small_graph, key=1)
    snap1 = store.snapshot()
    final1 = snap1.final_np.copy()
    rng = np.random.default_rng(1)
    store.update_features(np.arange(10),
                          rng.normal(size=(10, 16)).astype(np.float32))
    store.refresh()
    snap2 = store.snapshot()
    assert snap2.version == snap1.version + 1
    assert snap2 is not snap1
    # the old snapshot a reader may still hold is untouched
    np.testing.assert_array_equal(snap1.final_np, final1)
    with pytest.raises(Exception):            # frozen dataclass
        snap2.version = 99


def test_transient_refresh_fault_retried(small_graph):
    store, params, cfg = _built(small_graph, key=2)
    rng = np.random.default_rng(2)
    store.update_features([5], rng.normal(size=(1, 16))
                          .astype(np.float32))
    with faults.armed("store.mid_layer_refresh", at_hits=(0,),
                      exc=faults.TransientRefreshFault):
        info = store.refresh_with_recovery(max_retries=2,
                                           backoff_s=0.001)
    assert info["total_rows"] > 0 and "degraded" not in info
    assert store.refresh_stats()["transient_retries"] == 1
    assert not store.dirty
    np.testing.assert_array_equal(store.predict_meta(np.arange(20))[0],
                                  _forward_argmax(store, params, cfg)[:20])


def test_fatal_refresh_degrades_to_one_full_build(small_graph):
    store, params, cfg = _built(small_graph, key=3)
    rng = np.random.default_rng(3)
    store.update_features([4], rng.normal(size=(1, 16))
                          .astype(np.float32))
    with faults.armed("store.mid_layer_refresh", at_hits=(0,),
                      exc=faults.FatalSamplerFault):
        with pytest.warns(RuntimeWarning, match="DEGRADING"):
            info = store.refresh_with_recovery(max_retries=1,
                                               backoff_s=0.001)
    assert info.get("degraded") is True
    st = store.refresh_stats()
    assert st["degraded_builds"] == 1 and not store.dirty
    np.testing.assert_array_equal(store.predict_meta(np.arange(20))[0],
                                  _forward_argmax(store, params, cfg)[:20])


def test_fatal_after_degrade_surfaces_and_server_closes(small_graph):
    """before_swap armed at hits {0, 1}: the incremental publish dies,
    the degrade-to-build publish dies too → the fault surfaces on the
    query futures; the server stays closeable and the old snapshot is
    still the serving state."""
    store, params, cfg = _built(small_graph, key=4)
    v0 = store.version
    rng = np.random.default_rng(4)
    server = GNNServer(store, max_batch=8, max_wait_ms=1.0)
    try:
        server.classify([0, 1])
        store.update_features([7], rng.normal(size=(1, 16))
                              .astype(np.float32))
        with faults.armed("store.before_swap", at_hits=(0, 1),
                          exc=faults.FatalSamplerFault):
            fut = server.submit([2, 3])
            with pytest.warns(RuntimeWarning, match="DEGRADING"):
                with pytest.raises(faults.FatalSamplerFault):
                    fut.result(timeout=30.0)
        assert store.version == v0          # both partial versions dropped
    finally:
        server.close()
    assert np.array_equal(store.predict_meta([2, 3])[0],
                          np.argmax(store.snapshot().final_np[[2, 3]], -1))


def test_serve_before_reply_failpoint(small_graph):
    store, params, cfg = _built(small_graph, key=5)
    expect = _forward_argmax(store, params, cfg)
    with GNNServer(store, max_batch=4, max_wait_ms=1.0) as server:
        with faults.armed("serve.before_reply", at_hits=(0,)):
            with pytest.raises(faults.SimulatedCrash):
                server.classify([1, 2])
        # next batch is healthy — the failed reply never leaked state
        assert np.array_equal(server.classify([1, 2]), expect[[1, 2]])


def test_scheduler_thread_killed_by_crash_old_snapshot_serves(small_graph):
    store, params, cfg = _built(small_graph, key=6)
    v0 = store.version
    final0 = store.snapshot().final_np.copy()
    rng = np.random.default_rng(6)
    store.start_scheduler(refresh_every_updates=1, refresh_budget_ms=None,
                          tick_s=0.002)
    try:
        with faults.armed("store.mid_layer_refresh", at_hits=(0,)):
            store.update_features([11], rng.normal(size=(1, 16))
                                  .astype(np.float32))
            t = store._sched_thread
            t.join(timeout=10.0)            # SimulatedCrash kills it
            assert not t.is_alive()
        assert store.version == v0 and store.dirty
        np.testing.assert_array_equal(store.snapshot().final_np, final0)
    finally:
        store.stop_scheduler()
    store.refresh()                          # recovery after "restart"
    np.testing.assert_array_equal(store.predict_meta(np.arange(30))[0],
                                  _forward_argmax(store, params, cfg)[:30])


def test_scheduler_background_refresh_converges(small_graph):
    store, params, cfg = _built(small_graph, key=7)
    rng = np.random.default_rng(7)
    store.start_scheduler(refresh_every_updates=2, refresh_budget_ms=5.0,
                          tick_s=0.002)
    try:
        store.update_features(np.arange(4),
                              rng.normal(size=(4, 16)).astype(np.float32))
        deadline = time.monotonic() + 20.0
        while store.dirty and time.monotonic() < deadline:
            time.sleep(0.005)
    finally:
        store.stop_scheduler()
    assert not store.dirty
    st = store.refresh_stats()
    assert st["sched_refreshes"] >= 1 and st["pending_updates"] == 0
    np.testing.assert_array_equal(store.predict_meta(np.arange(30))[0],
                                  _forward_argmax(store, params, cfg)[:30])


# ---------------------------------------------------------------------------
# staleness SLO
# ---------------------------------------------------------------------------

def test_max_staleness_forces_synchronous_refresh(small_graph):
    store, params, cfg = _built(small_graph, key=8)
    rng = np.random.default_rng(8)
    with GNNServer(store, max_batch=8, max_wait_ms=1.0,
                   max_staleness_s=0.05) as server:
        server.classify([0])
        store.update_features([6], rng.normal(size=(1, 16))
                              .astype(np.float32))
        time.sleep(0.1)                      # age past the bound
        ans = server.submit([6, 7], with_meta=True).result(timeout=30.0)
        assert isinstance(ans, ServedAnswer)
        # the hard SLO: the breach forced a refresh, so the answer is
        # fresh, from the NEW version
        assert ans.staleness_s <= 0.05
        assert ans.snapshot_version == 2
        assert server.stats()["n_forced_refresh"] >= 1
    assert np.array_equal(ans.preds,
                          _forward_argmax(store, params, cfg)[[6, 7]])


def test_max_staleness_none_serves_stale(small_graph):
    store, params, cfg = _built(small_graph, key=9)
    before = _forward_argmax(store, params, cfg)
    rng = np.random.default_rng(9)
    with GNNServer(store, max_batch=8, max_wait_ms=1.0,
                   max_staleness_s=None) as server:
        store.update_features([2], rng.normal(size=(1, 16))
                              .astype(np.float32))
        time.sleep(0.02)
        ans = server.submit([2], with_meta=True).result(timeout=30.0)
        # no refresh on the serve path: old version, staleness reported
        assert ans.snapshot_version == 1
        assert ans.staleness_s > 0.0
        assert np.array_equal(ans.preds, before[[2]])
        assert server.stats()["n_forced_refresh"] == 0
    assert store.dirty                       # still pending


# ---------------------------------------------------------------------------
# overload protection
# ---------------------------------------------------------------------------

def test_overload_fail_fast(small_graph):
    store, params, cfg = _built(small_graph, key=10)
    server = GNNServer(store, max_batch=4, queue_depth=2,
                       overload="fail", start=False)
    futs = [server.submit([i]) for i in range(2)]
    with pytest.raises(ServerOverloadedError):
        server.submit([2])
    assert server.stats()["n_overload"] == 1
    server.start()
    try:
        for i, f in enumerate(futs):
            assert f.result(timeout=30.0)[0] == \
                _forward_argmax(store, params, cfg)[i]
    finally:
        server.close()


def test_overload_block_times_out(small_graph):
    store, params, cfg = _built(small_graph, key=11)
    server = GNNServer(store, queue_depth=1, overload="block",
                       submit_timeout_s=0.05, start=False)
    f0 = server.submit([0])
    t0 = time.monotonic()
    with pytest.raises(ServerOverloadedError):
        server.submit([1])
    assert time.monotonic() - t0 >= 0.04     # blocked, then failed
    server.close()
    with pytest.raises(RuntimeError, match="server closed"):
        f0.result(timeout=5.0)


def test_deadline_shed_before_lookup(small_graph):
    store, params, cfg = _built(small_graph, key=12)
    server = GNNServer(store, max_batch=8, max_wait_ms=1.0, start=False)
    expired = server.submit([0], deadline_s=0.01)
    live = server.submit([1])
    time.sleep(0.05)
    server.start()
    try:
        with pytest.raises(DeadlineExceededError):
            expired.result(timeout=30.0)
        assert live.result(timeout=30.0)[0] == \
            _forward_argmax(store, params, cfg)[1]
        assert server.stats()["n_shed"] == 1
    finally:
        server.close()


def test_close_drains_queue_and_fails_futures(small_graph):
    store, params, cfg = _built(small_graph, key=13)
    server = GNNServer(store, start=False)
    futs = [server.submit([i]) for i in range(3)]
    server.close()
    for f in futs:
        with pytest.raises(RuntimeError, match="server closed"):
            f.result(timeout=5.0)
    with pytest.raises(RuntimeError, match="closed"):
        server.submit([0])
    server.close()                            # idempotent


# ---------------------------------------------------------------------------
# bounded stats
# ---------------------------------------------------------------------------

def test_reservoir_bounds_latency_memory():
    r = _Reservoir(cap=16, seed=0)
    for i in range(1000):
        r.add(float(i))
    assert r.n == 1000 and len(r.values()) == 16
    # uniform sample: spans the stream, not just the head
    assert r.values().max() > 500

    stats = ServeStats(reservoir=8)
    for b in range(50):
        stats.record(1, 4, [1.0, 2.0, 3.0, 4.0], 0.0, 1.0,
                     version=b, staleness_s=0.01 * b)
    snap = stats.snapshot()
    assert len(stats._lat._buf) == 8          # bounded under traffic
    for key in ("n_requests", "n_queries", "n_batches",
                "mean_batch_queries", "p50_ms", "p99_ms", "mean_ms",
                "qps", "snapshot_version", "staleness_last_s",
                "staleness_max_s", "n_shed", "n_overload",
                "n_forced_refresh"):
        assert key in snap, key
    assert snap["n_requests"] == 50 and snap["snapshot_version"] == 49
    assert snap["staleness_max_s"] == pytest.approx(0.49)


# ---------------------------------------------------------------------------
# the headline property: concurrent writers vs queries
# ---------------------------------------------------------------------------

def _oracle_versions(small_graph, updates, key):
    """March a shadow store through the same update sequence; the
    consistent states a correct server may answer from are exactly the
    prefixes: argmax tables P_0 (initial) .. P_K (all applied)."""
    store, params, cfg = _built(small_graph, key=key)
    tables = [np.argmax(store.snapshot().final_np, -1)]
    for kind, a, b in updates:
        if kind == "feats":
            store.update_features(a, b)
        else:
            store.add_edges(a, b)
        store.refresh()
        tables.append(np.argmax(store.snapshot().final_np, -1))
    return tables


def _update_stream(n, feat_dim, rng):
    updates = []
    for i in range(6):
        if i % 3 == 2:                        # every third is structural
            src = rng.choice(n, size=2, replace=False)
            dst = rng.choice(n, size=2, replace=False)
            updates.append(("edges", src, dst))
        else:
            nodes = rng.choice(n, size=4, replace=False)
            feats = rng.normal(size=(4, feat_dim)).astype(np.float32)
            updates.append(("feats", nodes, feats))
    return updates


def test_concurrent_writers_vs_queries_prefix_consistent(small_graph):
    """Writer streaming feature AND edge updates while two query
    threads hammer classify: no crash, and every answer equals SOME
    prefix-consistent version's full recompute."""
    rng = np.random.default_rng(42)
    updates = _update_stream(small_graph.n, 16, rng)
    tables = _oracle_versions(small_graph, updates, key=20)

    store, params, cfg = _built(small_graph, key=20)
    qnodes = np.arange(0, small_graph.n, 7)   # fixed probe set
    answers, errors = [], []
    stop = threading.Event()

    server = GNNServer(store, max_batch=32, max_wait_ms=0.5,
                       max_staleness_s=0.25,
                       refresh_every_updates=2, refresh_budget_ms=20.0)
    try:
        def writer():
            try:
                for kind, a, b in updates:
                    if kind == "feats":
                        store.update_features(a, b)
                    else:
                        store.add_edges(a, b)
                    time.sleep(0.02)
            except Exception as e:            # pragma: no cover
                errors.append(e)
            finally:
                stop.set()

        def querier():
            try:
                while not stop.is_set() or len(answers) < 3:
                    ans = server.submit(qnodes, with_meta=True
                                        ).result(timeout=30.0)
                    answers.append(ans)
                    if len(answers) > 400:
                        break
            except Exception as e:            # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=writer)] + \
            [threading.Thread(target=querier) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120.0)
        # let the scheduler catch up, then one final query must match
        # the FULLY applied state
        deadline = time.monotonic() + 20.0
        while store.dirty and time.monotonic() < deadline:
            time.sleep(0.01)
        final = server.classify(qnodes)
    finally:
        server.close()

    assert not errors, errors
    assert len(answers) >= 3
    want = [t[qnodes] for t in tables]
    for ans in answers:
        assert any(np.array_equal(ans.preds, w) for w in want), \
            "answer matches NO consistent version (torn snapshot?)"
        assert ans.staleness_s <= 0.25 + 0.2  # SLO + scheduling slack
    np.testing.assert_array_equal(final, want[-1])
    # and the incremental end-state equals a from-scratch recompute
    np.testing.assert_array_equal(
        np.argmax(store.snapshot().final_np, -1),
        _forward_argmax(store, params, cfg))
