"""GNN core: both training paradigms, model equivalences, sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.graph import full_adjacency_dense, to_ell
from repro.core.sampler import expand_batch, sample_batch, gather_features
from repro.core.trainer import train_full_graph, train_minibatch


def _cfg(g, model="graphsage", n_layers=2, loss="ce", fanout=None):
    return GNNConfig(name="t", model=model, n_nodes=g.n,
                     feat_dim=g.feats.shape[1], hidden=32,
                     n_classes=g.n_classes, n_layers=n_layers,
                     fanout=tuple(fanout or (5, 3)[:n_layers]),
                     batch_size=64, loss=loss)


def test_ell_matches_dense_adjacency(small_graph):
    """ELL Ã-aggregation == dense Ã row-multiply (paper §2 definition)."""
    g = small_graph
    idx, w, w_self = to_ell(g)
    a = full_adjacency_dense(g)
    x = g.feats
    dense_agg = a @ x
    ell_agg = (np.einsum("nk,nkd->nd", w, x[idx])
               + w_self[:, None] * x)
    np.testing.assert_allclose(ell_agg, dense_agg, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("model", ["gcn", "graphsage", "gat"])
def test_minibatch_full_fanout_matches_fullgraph(small_graph, model):
    """With fan-out >= d_max and the full training set as one batch, the
    mini-batch forward equals the full-graph forward on a 1-layer model —
    the paper's 'full-graph is the (b=n, beta=d_max) special case'."""
    g = small_graph
    cfg = _cfg(g, model=model, n_layers=1, fanout=(g.d_max,))
    params = G.init_gnn(jax.random.key(0), cfg, g.feats.shape[1])

    idx, w, w_self = to_ell(g)
    full = G.full_graph_forward(params, cfg, jnp.asarray(g.feats),
                                jnp.asarray(idx), jnp.asarray(w),
                                jnp.asarray(w_self))
    rng = np.random.default_rng(0)
    targets = g.train_nodes[:64]
    fb = expand_batch(rng, g, targets, (g.d_max,))
    feats = [jnp.asarray(f) for f in gather_features(g, fb)]
    mini = G.minibatch_forward(
        params, cfg, feats,
        [jnp.asarray(m.astype(np.float32)) for m in fb.masks],
        [jnp.asarray(x) for x in fb.weights],
        [jnp.asarray(x) for x in fb.self_w])
    np.testing.assert_allclose(np.asarray(mini),
                               np.asarray(full)[targets],
                               atol=1e-4, rtol=1e-4)


def test_sampler_respects_fanout_and_graph(small_graph):
    g = small_graph
    rng = np.random.default_rng(3)
    fb = sample_batch(rng, g, 32, (5, 3))
    assert fb.nodes[1].shape == (32, 5)
    assert fb.nodes[2].shape == (32, 5, 3)
    # every masked-in neighbor must be a real neighbor
    for b in range(32):
        u = int(fb.nodes[0][b])
        nbrs = set(g.neighbors(u).tolist())
        for j in range(5):
            if fb.masks[0][b, j]:
                assert int(fb.nodes[1][b, j]) in nbrs
    # weights are zero exactly on padding
    assert ((fb.weights[0] > 0) == fb.masks[0]).all()


@pytest.mark.parametrize("loss", ["ce", "mse"])
def test_both_paradigms_learn(small_graph, loss):
    g = small_graph
    cfg = _cfg(g, loss=loss)
    lr = 0.3 if loss == "ce" else 0.05   # the paper tunes lr per loss
    rf = train_full_graph(g, cfg, lr=lr, n_iters=25)
    rm = train_minibatch(g, cfg, lr=lr, n_iters=25)
    assert rf.history.losses[-1] < rf.history.losses[0] * 0.9
    assert rm.history.losses[-1] < rm.history.losses[0]
    assert rf.final_test_acc > 1.5 / g.n_classes
    assert rm.final_test_acc > 1.5 / g.n_classes


def test_gat_output_is_class_logits(small_graph):
    g = small_graph
    cfg = _cfg(g, model="gat")
    params = G.init_gnn(jax.random.key(0), cfg, g.feats.shape[1])
    idx, w, w_self = to_ell(g)
    out = G.full_graph_forward(params, cfg, jnp.asarray(g.feats),
                               jnp.asarray(idx), jnp.asarray(w),
                               jnp.asarray(w_self))
    assert out.shape == (g.n, g.n_classes)
