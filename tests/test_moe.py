"""MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import moe as MOE


def _cfg(**kw):
    base = get_config("llama4-scout-17b-a16e", smoke=True)
    return base.__class__(**{**base.__dict__, **kw})


def test_moe_output_finite_and_gated(rng):
    cfg = _cfg()
    p = MOE.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)), jnp.float32)
    y, aux = MOE.moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 1.0 - 1e-3   # switch aux lower bound E*E*(1/E^2)


def test_moe_capacity_one_expert_identity():
    """With a single expert and huge capacity, MoE == its dense FFN."""
    cfg = _cfg(n_experts=1, capacity_factor=64.0)
    p = MOE.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(1, 32, cfg.d_model)), jnp.float32)
    y, _ = MOE.moe_block(p, x, cfg)
    # dense reference with the same expert weights
    g = x @ p["w_gate"][0]
    u = x @ p["w_up"][0]
    ref = (jax.nn.silu(g) * u) @ p["w_down"][0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)


def test_moe_capacity_drops_overflow(rng):
    """With capacity factor ~0, (almost) every token is dropped -> y ~ 0."""
    cfg = _cfg(capacity_factor=1e-9)
    p = MOE.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(rng.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    y, _ = MOE.moe_block(p, x, cfg)
    # capacity clamps to 1 slot per expert per group: most tokens zeroed
    zero_rows = np.mean(np.abs(np.asarray(y)).sum(-1) < 1e-6)
    assert zero_rows > 0.3


def test_moe_decode_single_token(rng):
    cfg = _cfg()
    p = MOE.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(rng.normal(size=(4, 1, cfg.d_model)), jnp.float32)
    y, _ = MOE.moe_block(p, x, cfg)
    assert y.shape == x.shape
    # capacity >= 1 per group of 1 token -> nothing dropped
    assert float(jnp.min(jnp.abs(np.asarray(y)).sum(-1))) > 0
