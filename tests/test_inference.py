"""Layer-wise full-graph inference (PR 7): per-layer equivalence with
the naive ``full_graph_forward`` oracle.

Contract (ISSUE 7 tentpole):
- per-layer allclose for GCN + SAGE (and GAT), kernel AND einsum paths,
  at chunk sizes that do and do not divide n;
- prefetch on/off is BIT-identical (same chunks, same compiled ops);
- on a 1-device NODES mesh the kernel path is BIT-identical to the
  unsharded kernel path (inherited from ``neighbor_agg_sharded``);
- on a 4-device CPU mesh (own subprocess, mirroring
  tests/test_sharded_kernel.py) the sharded layer-wise pass matches the
  naive einsum forward.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as sh
from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.graph import to_ell
from repro.core.inference import layerwise_embeddings, layerwise_logits

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(g, **kw):
    base = dict(name="inf", model="gcn", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=8,
                n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                batch_size=32, loss="ce", use_agg_kernel=False,
                agg_interpret=True, agg_b_tile=4, agg_d_tile=8,
                agg_k_slab=2)
    base.update(kw)
    return GNNConfig(**base)


def _naive_layers(params, cfg, g):
    idx, w, ws = to_ell(g)
    _, layers = G.full_graph_forward(
        params, cfg, jnp.asarray(g.feats), jnp.asarray(idx),
        jnp.asarray(w), jnp.asarray(ws), return_layers=True)
    return layers


def _assert_layers_close(got, want, **tol):
    tol = tol or dict(rtol=1e-5, atol=1e-5)
    assert len(got) == len(want)
    for li, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   err_msg=f"layer {li}", **tol)


@pytest.mark.parametrize("model,kernel", [
    ("gcn", False), ("gcn", True),
    ("graphsage", False), ("graphsage", True),
    ("gat", False),
])
# 37 does not divide n=300, 150 does, 999 > n collapses to one chunk
@pytest.mark.parametrize("chunk", [37, 150, 999])
def test_layerwise_matches_naive(small_graph, model, kernel, chunk):
    cfg = _cfg(small_graph, model=model, use_agg_kernel=kernel)
    params = G.init_gnn(jax.random.key(0), cfg,
                        small_graph.feats.shape[1])
    run = layerwise_embeddings(params, cfg, small_graph, chunk_size=chunk)
    _assert_layers_close(run.layers, _naive_layers(params, cfg,
                                                   small_graph))
    # stats populated and consistent
    assert run.stats["n_chunks"] == -(-small_graph.n
                                      // min(chunk, small_graph.n))
    assert run.stats["chunk_steps"] == cfg.n_layers * run.stats["n_chunks"]
    assert run.stats["total_s"] > 0 and run.stats["ms_per_node"] > 0


def test_layerwise_three_layers_width_shrink(small_graph):
    """3 layers with hidden < feat_dim exercises the pre-aggregation
    width-shrinking transform on every layer."""
    for model in ("gcn", "graphsage"):
        cfg = _cfg(small_graph, model=model, n_layers=3, fanout=(4, 3, 3),
                   hidden=8)
        params = G.init_gnn(jax.random.key(1), cfg,
                            small_graph.feats.shape[1])
        run = layerwise_embeddings(params, cfg, small_graph,
                                   chunk_size=64)
        _assert_layers_close(run.layers,
                             _naive_layers(params, cfg, small_graph))


def test_layerwise_logits_matches_forward(small_graph):
    cfg = _cfg(small_graph, model="graphsage")
    params = G.init_gnn(jax.random.key(2), cfg,
                        small_graph.feats.shape[1])
    idx, w, ws = to_ell(small_graph)
    want = G.full_graph_forward(params, cfg,
                                jnp.asarray(small_graph.feats),
                                jnp.asarray(idx), jnp.asarray(w),
                                jnp.asarray(ws))
    got = layerwise_logits(params, cfg, small_graph, chunk_size=50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_prefetch_off_bit_identical(small_graph):
    cfg = _cfg(small_graph, model="graphsage", use_agg_kernel=True)
    params = G.init_gnn(jax.random.key(3), cfg,
                        small_graph.feats.shape[1])
    r1 = layerwise_embeddings(params, cfg, small_graph, chunk_size=40,
                              prefetch=True)
    r2 = layerwise_embeddings(params, cfg, small_graph, chunk_size=40,
                              prefetch=False)
    for a, b in zip(r1.layers, r2.layers):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("model", ["gcn", "graphsage"])
def test_one_device_mesh_bit_equal(small_graph, model):
    """Sharded kernel path on a 1-device mesh == unsharded kernel path,
    bit for bit, per layer (the PR 5 contract carried into inference)."""
    cfg = _cfg(small_graph, model=model, use_agg_kernel=True)
    params = G.init_gnn(jax.random.key(4), cfg,
                        small_graph.feats.shape[1])
    base = layerwise_embeddings(params, cfg, small_graph, chunk_size=64)
    shrd = layerwise_embeddings(params, cfg, small_graph, chunk_size=64,
                                mesh=sh.node_mesh(1))
    for a, b in zip(base.layers, shrd.layers):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_empty_graph_rejected(small_graph):
    from repro.core.inference import layerwise_layers
    cfg = _cfg(small_graph)
    params = G.init_gnn(jax.random.key(0), cfg,
                        small_graph.feats.shape[1])
    idx, w, ws = to_ell(small_graph)
    with pytest.raises(ValueError, match="n=0"):
        layerwise_layers(params, cfg, np.zeros((0, 16), np.float32),
                         (idx, w, ws))


# ---------------------------------------------------------------------------
# 4-device CPU mesh (subprocess): sharded layer-wise == naive einsum
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro import sharding as sh
from repro.data import make_sbm_graph
from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.graph import to_ell
from repro.core.inference import layerwise_embeddings

mesh = sh.node_mesh()
g = make_sbm_graph(n=202, n_classes=4, avg_degree=8, feat_dim=16, seed=5)
idx, w, ws = to_ell(g)
for model in ("gcn", "graphsage"):
    base = GNNConfig(name="md", model=model, n_nodes=g.n, feat_dim=16,
                     hidden=8, n_classes=g.n_classes, n_layers=2,
                     fanout=(4, 3), batch_size=30, loss="ce")
    kcfg = dataclasses.replace(base, use_agg_kernel=True,
                               agg_interpret=True, agg_b_tile=4,
                               agg_d_tile=8, agg_k_slab=2)
    params = G.init_gnn(jax.random.key(0), kcfg, 16)
    _, want = G.full_graph_forward(params, base, jnp.asarray(g.feats),
                                   jnp.asarray(idx), jnp.asarray(w),
                                   jnp.asarray(ws), return_layers=True)
    # chunk size 60 does not divide n=202; shard padding is internal
    run = layerwise_embeddings(params, kcfg, g, chunk_size=60, mesh=mesh)
    for li, (a, b) in enumerate(zip(run.layers, want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"{model} layer {li}")
print("MULTIDEV_INFERENCE_OK")
"""


def test_layerwise_on_multidevice_cpu_mesh():
    """4 virtual CPU devices (own process: the XLA device-count flag
    must be set before jax initializes): the NODES-sharded layer-wise
    pass matches the naive einsum forward per layer, GCN + SAGE."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEV_INFERENCE_OK" in out.stdout
