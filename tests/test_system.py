"""End-to-end behaviour tests for the paper's system: loss-goes-down
training on both GNN paradigms, an LM end-to-end step chain, metric
plumbing, and the roofline/HLO analysis utilities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig, get_config, INPUT_SHAPES, \
    shape_applicable


def test_lm_loss_decreases_over_steps():
    """Train a reduced granite for 30 steps on Markov tokens."""
    from repro.data import token_batches
    from repro.models import model as M
    from repro.models import steps as S
    from repro.optim import adamw

    cfg = get_config("granite-3-2b", smoke=True)
    params = M.init_model(jax.random.key(0), cfg)
    opt, step = S.make_train_step(cfg, optimizer=adamw(3e-3))
    opt_state = opt.init(params)
    stepj = jax.jit(step)
    losses = []
    for i, hb in enumerate(token_batches(cfg.vocab_size, 8, 64,
                                         n_batches=30)):
        batch = {"tokens": jnp.asarray(hb["tokens"]),
                 "labels": jnp.asarray(hb["labels"])}
        params, opt_state, m = stepj(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_gnn_full_vs_mini_comparable_accuracy(small_graph):
    """Table-1-style check: well-tuned mini-batch is within a few points
    of full-graph on the same graph."""
    from repro.core.trainer import train_full_graph, train_minibatch
    g = small_graph
    cfg = GNNConfig(name="t", model="graphsage", n_nodes=g.n,
                    feat_dim=g.feats.shape[1], hidden=32,
                    n_classes=g.n_classes, n_layers=2, fanout=(5, 3),
                    batch_size=64, loss="ce")
    rf = train_full_graph(g, cfg, lr=0.3, n_iters=40)
    rm = train_minibatch(g, cfg, lr=0.3, n_iters=40)
    assert abs(rf.final_test_acc - rm.final_test_acc) < 0.15


def test_shape_applicability_matrix():
    """The assigned skip rules: long_500k only for sub-quadratic archs."""
    expect_runs_long = {"mamba2-130m", "zamba2-7b", "gemma3-12b",
                        "llama4-scout-17b-a16e",
                        "llama4-maverick-400b-a17b"}
    long = INPUT_SHAPES["long_500k"]
    from repro.configs.base import list_archs
    for arch in list_archs():
        cfg = get_config(arch)
        if cfg.family == "gnn":
            continue
        ok, why = shape_applicable(cfg, long)
        assert ok == (arch in expect_runs_long), (arch, why)
        # every arch runs the other three shapes
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = shape_applicable(cfg, INPUT_SHAPES[s])
            assert ok


def test_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
  %p0 = f32[128,256] parameter(0)
  %ar = f32[128,256] all-reduce(%p0), replica_groups={}
  %ag = bf16[16,64] all-gather(%conv), dimensions={0}
  %conv = bf16[8,64] convert(%p0)
  %cp = f32[4] collective-permute(%small)
  %small = f32[4] constant(0)
"""
    got = collective_bytes(hlo)
    # wire model: all-reduce 2x operand; all-gather = OUTPUT bytes
    assert got["all-reduce"] == 2 * 128 * 256 * 4
    assert got["all-gather"] == 16 * 64 * 2
    assert got["collective-permute"] == 16
    assert got["total"] == sum(v for k, v in got.items() if k != "total")


def test_roofline_terms():
    from repro.launch.roofline import roofline, PEAK_FLOPS, HBM_BW, ICI_BW
    r = roofline(PEAK_FLOPS, HBM_BW * 0.5, ICI_BW * 0.25)
    assert np.isclose(r["compute_s"], 1.0)
    assert r["dominant"] == "compute"
    assert np.isclose(r["compute_fraction"], 1.0)


def test_logical_axis_resolution():
    from repro import sharding as sh

    class FakeMesh:
        axis_names = ("pod", "data", "model")
    m = sh.axis_map(FakeMesh())
    assert m[sh.BATCH] == ("pod", "data")
    assert m[sh.FSDP] == "data"

    class FakeMesh2:
        axis_names = ("data", "model")
    m2 = sh.axis_map(FakeMesh2())
    assert m2[sh.BATCH] == "data"
    assert m2[sh.ALL] == ("data", "model")


def test_serve_chain_end_to_end():
    from repro.models import model as M
    cfg = get_config("stablelm-1.6b", smoke=True)
    params = M.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                                   jnp.int32)}
    logits, cache = M.prefill(params, cfg, batch)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(4):
        toks.append(tok)
        logits, cache = M.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = jnp.concatenate(toks, 1)
    assert out.shape == (2, 4)
    assert int(cache["pos"]) == 36
