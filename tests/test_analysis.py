"""Tests for the ``repro.analysis`` static checkers (ISSUE 9).

Three kinds of coverage:

* **seeded-broken fixtures** — each checker must flag its fixture
  (``repro.analysis.fixtures``): the unmatched-DMA-wait kernel, the
  step closure capturing a big host ndarray, the f64 widening, and the
  class writing shared state from a worker thread;
* **clean tree** — the repo's own kernels/modules produce no gating
  finding modulo ``analysis/allowlist.toml`` (the same invariant
  `make analyze` gates in CI, minus the full 14-variant jaxpr sweep —
  one representative variant keeps this suite fast);
* **plumbing** — allowlist parsing/matching, VMEM budget arithmetic,
  index-bounds checks.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.analysis import findings as F
from repro.analysis import fixtures as FX
from repro.analysis import pallas_audit as PA
from repro.analysis import thread_audit as TA

ALLOWLIST = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "src", "repro", "analysis", "allowlist.toml")


def _kept(findings):
    entries, bad = F.load_allowlist(ALLOWLIST)
    assert not bad, [str(b) for b in bad]
    kept, _ = F.apply_allowlist(findings, entries)
    return kept


# ---------------------------------------------------------------------------
# seeded-broken fixtures: every checker must catch its fixture
# ---------------------------------------------------------------------------

FIXTURE_CHECKER = {"dma": "pallas", "constant": "jaxpr",
                   "f64": "jaxpr", "thread": "thread"}
FIXTURE_DETAIL = {"dma": "never waited",
                  "constant": "host np.ndarray constant",
                  "f64": "float64",
                  "thread": "written without a lock"}


@pytest.mark.parametrize("name", FX.FIXTURES)
def test_fixture_is_flagged(name):
    fs = FX.run_fixture(name)
    gate = F.gating(fs)
    assert gate, f"fixture {name} produced no gating finding"
    assert all(f.checker == FIXTURE_CHECKER[name] for f in gate)
    assert any(FIXTURE_DETAIL[name] in f.detail for f in gate), \
        [str(f) for f in gate]


def test_dma_fixture_flags_every_leaked_copy():
    # nk=3 over a (2, 2) grid: the tail slab's b_tile*k_slab = 4 copies
    # leak in each of the 4 output tiles
    fs = FX.run_fixture("dma")
    assert len(fs) == 16
    assert all("never waited" in f.detail for f in fs)


def test_thread_fixture_names_the_attr():
    fs = FX.run_fixture("thread")
    assert [f.site for f in fs] == ["fixture_mod.LossyCounter.count"]


# ---------------------------------------------------------------------------
# clean tree modulo allowlist
# ---------------------------------------------------------------------------

def test_repo_thread_audit_clean():
    assert not F.gating(_kept(TA.audit_threads()))


def test_repo_pallas_audit_clean():
    fs = PA.audit_budgets() + PA.audit_dma_pairing()
    assert not F.gating(_kept(fs))


def test_repo_index_tables_clean():
    from repro.analysis.jaxpr_audit import audit_graph
    assert not F.gating(_kept(PA.audit_index_tables(audit_graph(n=96))))


def test_repo_jaxpr_audit_clean_one_variant():
    # the full 14-variant sweep is `make analyze` territory (~1 min,
    # cached by src digest); one kernel-path variant here keeps the
    # hazard walks + retrace-stability checks in tier-1
    from repro.analysis.jaxpr_audit import (Variant, audit_graph,
                                            audit_variant)
    graph = audit_graph(n=96)
    fs, rec = audit_variant(graph, Variant("fullgraph", True))
    assert not F.gating(_kept(fs))
    assert rec["step_cache_hit"] is True
    assert rec["n_eqns"] > 0 and len(rec["jaxpr_hash"]) == 16


def test_allowlist_stays_small():
    entries, bad = F.load_allowlist(ALLOWLIST)
    assert not bad
    assert len(entries) <= 3, \
        "ISSUE 9 acceptance: fix findings instead of allowlisting them"


# ---------------------------------------------------------------------------
# allowlist plumbing
# ---------------------------------------------------------------------------

def test_parse_allowlist_roundtrip():
    text = """
    # comment
    [[allow]]
    checker = "thread"   # trailing comment
    site = "mod.Cls.attr"
    reason = "a # inside quotes stays"
    """
    (e,) = F.parse_allowlist(text)
    assert e == {"checker": "thread", "site": "mod.Cls.attr",
                 "reason": "a # inside quotes stays"}


@pytest.mark.parametrize("bad", [
    "[[allow]]\nchecker = unquoted\n",
    "stray line\n",
])
def test_parse_allowlist_rejects(bad):
    with pytest.raises(ValueError):
        F.parse_allowlist(bad)


def test_apply_allowlist_prefix_and_checker():
    fs = [F.Finding("thread", "error", "mod.Cls.attr", "x"),
          F.Finding("thread", "error", "mod.Cls.attr2", "x"),
          F.Finding("pallas", "error", "mod.Cls.attr", "x")]
    kept, supp = F.apply_allowlist(
        fs, [{"checker": "thread", "site": "mod.Cls.attr",
              "reason": "r"}])
    # prefix match suppresses both thread sites but not the pallas one
    assert [f.checker for f in kept] == ["pallas"]
    assert len(supp) == 2


def test_finding_rejects_bad_severity():
    with pytest.raises(ValueError):
        F.Finding("jaxpr", "fatal", "s", "d")


# ---------------------------------------------------------------------------
# budget + bounds arithmetic
# ---------------------------------------------------------------------------

def test_tiled_budget_matches_hand_formula():
    parts = PA.tiled_agg_budget(8, 128, 4)
    # rows double buffer + f32 acc + double-buffered w and out blocks
    assert sum(parts.values()) == (2 * 4 * 8 * 128 * 4 + 8 * 128 * 4
                                   + 2 * 8 * 4 * 4 + 2 * 8 * 128 * 4)
    fused = PA.tiled_agg_budget(8, 128, 4, fuse_self=True)
    assert sum(fused.values()) - sum(parts.values()) == \
        2 * 8 * 4 + 2 * 8 * 128 * 4


def test_budget_gate_fires_over_limit():
    row = PA.budget_row("huge", "case",
                        {"scratch": PA.VMEM_LIMIT["tpu"] + 1})
    (f,) = PA.audit_budgets([row])
    assert f.severity == "error" and "exceeds" in f.detail


def test_budget_gate_warns_near_limit():
    # one byte over the threshold vanishes in vmem_frac's 5-decimal
    # rounding; one percent over does not
    row = PA.budget_row(
        "big", "case",
        {"scratch": int(PA.VMEM_LIMIT["tpu"]
                        * (PA.WARN_FRACTION + 0.01))})
    (f,) = PA.audit_budgets([row])
    assert f.severity == "warning"


def test_index_bounds():
    ok = np.array([[0, 3], [1, 2]], np.int32)
    assert PA.check_index_bounds(ok, 4, "s") == []
    (f,) = PA.check_index_bounds(np.array([4], np.int32), 4, "s")
    assert f.severity == "error"
    (f,) = PA.check_index_bounds(np.array([-1], np.int32), 4, "s")
    assert f.severity == "error"


def test_simulated_bad_index_is_flagged():
    # an id past the table's rows must surface through the DMA harness
    from repro.kernels.neighbor_agg.neighbor_agg import _make_tiled_kernel
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 16, size=8 * 6).astype(np.int32)
    idx[5] = 99
    fs = PA.simulate_dma_pairing(_make_tiled_kernel, nk=3, n_rows=16,
                                 fuse_self=False, idx=idx,
                                 site="fixture:oob")
    assert any("outside [0, 16)" in f.detail for f in fs)
