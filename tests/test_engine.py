"""Unified training engine: legacy-wrapper equivalence against recorded
pre-refactor goldens, callback ordering, early-stop semantics, the
(b, β) sweep runner, staging-ring reuse, and config validation."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core.engine import (Callback, EarlyStop, FullGraphSource,
                               HistoryCallback, SampledSource, Trainer,
                               TrainPlan)
from repro.core.experiment import run_experiment, save_rows, sweep
from repro.core.metrics import (History, iteration_to_accuracy,
                                time_to_accuracy)
from repro.core.prefetch import HostStagingRing
from repro.core.trainer import train_full_graph, train_minibatch

GOLDENS = os.path.join(os.path.dirname(__file__), "goldens",
                       "trainer_seed.json")


def _cfg(g, **kw):
    base = dict(name="t", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=32,
                n_classes=g.n_classes, n_layers=2, fanout=(5, 3),
                batch_size=64, loss="ce")
    base.update(kw)
    return GNNConfig(**base)


# ---------------------------------------------------------------------------
# Legacy-wrapper equivalence: bit-for-bit vs the pre-engine loops
# ---------------------------------------------------------------------------

def _assert_matches(gold, res, name):
    h = res.history
    assert h.losses == gold["losses"], name
    assert h.val_accs == gold["val_accs"], name
    assert h.full_losses == gold["full_losses"], name
    assert h.full_loss_iters == gold["full_loss_iters"], name
    assert h.nodes_processed == gold["nodes_processed"], name
    assert res.final_test_acc == gold["final_test_acc"], name


@pytest.fixture(scope="module")
def goldens():
    with open(GOLDENS) as f:
        return json.load(f)


def test_fullgraph_wrapper_matches_seed_golden(small_graph, goldens):
    """train_full_graph == the pre-engine loop, bit-for-bit at fixed seed
    (goldens recorded from the PR-1 code before the Trainer refactor)."""
    g = small_graph
    cfg = _cfg(g, name="golden")
    res = train_full_graph(g, cfg, lr=0.3, n_iters=12, eval_every=5,
                           seed=0)
    _assert_matches(goldens["full_graph"], res, "full_graph")


def test_fullgraph_wrapper_target_loss_golden(small_graph, goldens):
    g = small_graph
    cfg = _cfg(g, name="golden")
    res = train_full_graph(g, cfg, lr=0.3, n_iters=50, eval_every=10,
                           seed=0, target_loss=1.2)
    _assert_matches(goldens["full_graph_target"], res, "full_graph_target")
    assert res.stop_reason == "target_loss<=1.2"


@pytest.mark.parametrize("prefetch,key", [(False, "minibatch_sync"),
                                          (True, "minibatch_prefetch")])
def test_minibatch_wrapper_matches_seed_golden(small_graph, goldens,
                                               prefetch, key):
    g = small_graph
    cfg = _cfg(g, name="golden")
    res = train_minibatch(g, cfg, lr=0.3, n_iters=12, eval_every=5,
                          seed=0, track_full_loss_every=4,
                          prefetch=prefetch)
    _assert_matches(goldens[key], res, key)


def test_minibatch_wrapper_explicit_b_fanout_golden(small_graph, goldens):
    g = small_graph
    cfg = _cfg(g, name="golden")
    res = train_minibatch(g, cfg, lr=0.3, n_iters=8, batch_size=32,
                          fanouts=(4, 2), eval_every=3, seed=7,
                          prefetch=True)
    _assert_matches(goldens["minibatch_b32"], res, "minibatch_b32")


def test_fullgraph_max_deg_uses_capped_ell_everywhere():
    """With max_deg set, training AND evaluation run on the capped ELL
    (legacy-loop semantics) — the full-width ELL is never built."""
    from repro.data import make_sbm_graph
    g = make_sbm_graph(n=200, n_classes=4, avg_degree=10, feat_dim=16,
                       seed=3)
    res = train_full_graph(g, _cfg(g), lr=0.3, n_iters=3, max_deg=4)
    assert len(res.history.losses) == 3
    cache = g._ell_cache
    assert 4 in cache and g.d_max not in cache


def test_run_experiment_custom_source_labels_row(small_graph):
    """A custom source overrides `paradigm`; the row must describe the
    source that actually ran, not the default paradigm string."""
    g = small_graph
    row = run_experiment(g, _cfg(g), TrainPlan(lr=0.3, n_iters=2),
                         source=FullGraphSource())
    assert row["paradigm"] == "fullgraph"
    assert row["b"] == len(g.train_nodes)
    row = run_experiment(g, _cfg(g), TrainPlan(lr=0.3, n_iters=2),
                         source=SampledSource(batch_size=16,
                                              fanouts=(2, 2)))
    assert row["paradigm"] == "minibatch"
    assert row["b"] == 16 and row["fanouts"] == "2x2"


def test_staging_ring_off_is_identical(small_graph):
    """Buffer reuse is a pure transport optimization: the loss sequence
    with the staging ring disabled is bit-identical."""
    g = small_graph
    cfg = _cfg(g)
    plan = TrainPlan(lr=0.3, n_iters=6, seed=0)
    r_ring = Trainer(g, cfg, plan, source=SampledSource()).run()
    r_flat = Trainer(g, cfg, plan,
                     source=SampledSource(reuse_buffers=False)).run()
    assert r_ring.history.losses == r_flat.history.losses


# ---------------------------------------------------------------------------
# Callbacks
# ---------------------------------------------------------------------------

class Recorder(Callback):
    def __init__(self):
        self.events = []

    def on_train_start(self, state):
        self.events.append(("train_start", state.it))

    def on_step(self, state):
        self.events.append(("step", state.it))

    def on_eval(self, state):
        self.events.append(("eval", state.it, state.val_acc))

    def on_stop(self, state):
        self.events.append(("stop", state.it, state.stop_reason))

    def on_train_end(self, state):
        self.events.append(("train_end", state.it))


def test_callback_ordering(small_graph):
    g = small_graph
    rec = Recorder()
    plan = TrainPlan(lr=0.3, n_iters=5, eval_every=2, seed=0)
    Trainer(g, _cfg(g), plan, source=SampledSource(),
            extra_callbacks=[rec]).run()
    kinds = [e[0] for e in rec.events]
    assert kinds[0] == "train_start" and kinds[-1] == "train_end"
    # every iteration fires on_step; eval iterations (0, 2, 4) fire
    # on_eval immediately after their on_step
    assert kinds[1:-1] == ["step", "eval", "step", "step", "eval",
                           "step", "step", "eval"]
    assert [e[1] for e in rec.events if e[0] == "eval"] == [0, 2, 4]
    assert all(e[2] is not None for e in rec.events if e[0] == "eval")


def test_callbacks_fire_in_list_order(small_graph):
    g = small_graph
    order = []

    class A(Callback):
        def on_step(self, state):
            order.append("a")

    class B(Callback):
        def on_step(self, state):
            order.append("b")

    plan = TrainPlan(lr=0.3, n_iters=2, seed=0)
    Trainer(g, _cfg(g), plan, source=FullGraphSource(),
            extra_callbacks=[A(), B()]).run()
    assert order == ["a", "b", "a", "b"]


def test_early_stop_target_acc(small_graph):
    """target_acc stops on the first eval that crosses it; on_stop fires
    exactly once, on the stopping iteration."""
    g = small_graph
    rec = Recorder()
    plan = TrainPlan(lr=0.3, n_iters=50, eval_every=1, target_acc=0.0,
                     seed=0)
    res = Trainer(g, _cfg(g), plan, source=FullGraphSource(),
                  extra_callbacks=[rec]).run()
    assert len(res.history.losses) == 1        # stopped after iter 0
    assert res.stop_reason == "target_acc>=0.0"
    stops = [e for e in rec.events if e[0] == "stop"]
    assert stops == [("stop", 0, "target_acc>=0.0")]


def test_early_stop_records_crossing_iteration(small_graph):
    """Stop fires AFTER History records the crossing loss (legacy loop
    semantics): the last recorded loss is the one <= target."""
    g = small_graph
    plan = TrainPlan(lr=0.3, n_iters=100, target_loss=1.0, seed=0)
    res = Trainer(g, _cfg(g), plan, source=FullGraphSource()).run()
    assert res.history.losses[-1] <= 1.0
    assert all(l > 1.0 for l in res.history.losses[:-1])


def test_checkpoint_callback(small_graph, tmp_path):
    from repro.checkpoint import (latest_step, load_metadata,
                                  restore_checkpoint)
    g = small_graph
    plan = TrainPlan(lr=0.3, n_iters=7, ckpt_every=3, seed=0,
                     ckpt_dir=str(tmp_path))
    tr = Trainer(g, _cfg(g), plan, source=FullGraphSource())
    res = tr.run()
    # periodic saves at 3, 6 + final save at last iter
    assert latest_step(str(tmp_path)) == 6
    # checkpoints are full TrainerState snapshots: params AND opt_state
    # in the npz, the resume engine_state in the metadata
    like = {"params": res.params, "opt_state": tr.opt.init(res.params)}
    restored = restore_checkpoint(str(tmp_path), like)
    np.testing.assert_array_equal(np.asarray(res.params[0]["w_self"]),
                                  restored["params"][0]["w_self"])
    es = load_metadata(str(tmp_path))["engine_state"]
    assert es["it"] == 6 and es["seed"] == 0
    assert len(es["history"]["losses"]) == 7


# ---------------------------------------------------------------------------
# TrainPlan: optimizer/schedule resolution from repro.optim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt_kw", [dict(optimizer="sgd", momentum=0.9),
                                    dict(optimizer="adamw", lr=1e-2),
                                    dict(schedule="cosine", warmup=2)])
def test_plan_optimizers_train(small_graph, opt_kw):
    g = small_graph
    plan = TrainPlan(lr=opt_kw.pop("lr", 0.3), n_iters=15, seed=0,
                     **opt_kw)
    res = Trainer(g, _cfg(g), plan, source=FullGraphSource()).run()
    assert res.history.losses[-1] < res.history.losses[0]


def test_plan_rejects_unknown_optimizer():
    with pytest.raises(ValueError, match="unknown optimizer"):
        TrainPlan(optimizer="lion").make_optimizer()
    with pytest.raises(ValueError, match="unknown schedule"):
        TrainPlan(schedule="linear").make_schedule()


# ---------------------------------------------------------------------------
# Sweep runner
# ---------------------------------------------------------------------------

def test_sweep_2x2_smoke(small_graph, tmp_path):
    g = small_graph
    cfg = _cfg(g, n_layers=1, fanout=(5,))
    plan = TrainPlan(lr=0.3, n_iters=3, eval_every=2)
    rows = sweep(g, cfg, plan, batch_sizes=[16, 32],
                 fanout_grid=[(2,), 4], include_fullgraph=True)
    assert len(rows) == 1 + 2 * 2
    assert rows[0]["paradigm"] == "fullgraph"
    assert rows[0]["b"] == len(g.train_nodes)
    assert {(r["b"], r["fanouts"]) for r in rows[1:]} == {
        (16, "2"), (16, "4"), (32, "2"), (32, "4")}
    assert all(r["iters"] == 3 for r in rows)
    paths = save_rows("engine_sweep_smoke", rows, out_dir=str(tmp_path))
    assert os.path.exists(paths["json"]) and os.path.exists(paths["csv"])
    loaded = json.load(open(paths["json"]))
    assert len(loaded) == len(rows) and loaded[0]["paradigm"] == "fullgraph"


def test_sweep_namespaces_checkpoints_per_grid_point(small_graph,
                                                     tmp_path):
    """Grid points must not overwrite each other's ckpt_{step}.npz."""
    g = small_graph
    cfg = _cfg(g, n_layers=1, fanout=(5,))
    plan = TrainPlan(lr=0.3, n_iters=3, ckpt_every=2,
                     ckpt_dir=str(tmp_path))
    sweep(g, cfg, plan, batch_sizes=[16, 32], fanout_grid=[(2,)])
    subdirs = sorted(p.name for p in tmp_path.iterdir() if p.is_dir())
    assert subdirs == ["b16_f2_s0", "b32_f2_s0"]
    for d in subdirs:
        assert any(f.name.startswith("ckpt_")
                   for f in (tmp_path / d).iterdir())


def test_sweep_rejects_bad_grid(small_graph):
    g = small_graph
    cfg = _cfg(g, n_layers=1, fanout=(5,))
    plan = TrainPlan(n_iters=2)
    with pytest.raises(ValueError, match="fan-outs must be positive"):
        sweep(g, cfg, plan, batch_sizes=[16], fanout_grid=[(0,)])
    with pytest.raises(ValueError, match="batch_size"):
        sweep(g, cfg, plan, batch_sizes=[-4], fanout_grid=[(2,)])


def test_run_experiment_validates_override_kwargs(small_graph):
    """b/fanouts overrides must hit the fail-fast validation, not crash
    deep inside the sampler."""
    g = small_graph
    plan = TrainPlan(n_iters=1)
    with pytest.raises(ValueError, match="batch_size"):
        run_experiment(g, _cfg(g), plan, b=-5)
    with pytest.raises(ValueError, match="fan-outs must be positive"):
        run_experiment(g, _cfg(g), plan, fanouts=(0, 3))
    with pytest.raises(ValueError, match="one β per layer"):
        run_experiment(g, _cfg(g), plan, fanouts=(3,))


def test_run_experiment_fullgraph_row(small_graph):
    g = small_graph
    row = run_experiment(g, _cfg(g), TrainPlan(lr=0.3, n_iters=3),
                         paradigm="fullgraph", report_loss=0.1)
    assert row["paradigm"] == "fullgraph"
    assert row["iters"] == 3 and "iter_to_loss" in row
    with pytest.raises(ValueError, match="paradigm"):
        run_experiment(g, _cfg(g), TrainPlan(n_iters=1), paradigm="nope")


# ---------------------------------------------------------------------------
# Config validation (fail fast before the Pallas kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [dict(agg_b_tile=0), dict(agg_d_tile=-1),
                                 dict(agg_k_slab=0), dict(batch_size=0),
                                 dict(fanout=(5, 0)), dict(fanout=(5,)),
                                 dict(max_degree=0), dict(hidden=0)])
def test_gnnconfig_validate_rejects(small_graph, bad):
    cfg = _cfg(small_graph, **bad)
    with pytest.raises(ValueError):
        cfg.validate()


def test_gnnconfig_validate_accepts_good(small_graph):
    _cfg(small_graph).validate()


# ---------------------------------------------------------------------------
# Metrics: eval-iteration bookkeeping (the satellite fix)
# ---------------------------------------------------------------------------

def test_iteration_to_accuracy_uses_eval_iters():
    """val_accs recorded every 5 iters: crossing on the 3rd eval means
    iteration 11, not list index 3."""
    h = History()
    h.start()
    for it in range(20):
        val = [0.1, 0.3, 0.9, 0.95][it // 5] if it % 5 == 0 else None
        h.record(2.0 - it * 0.1, val, nodes=1)
    assert h.val_acc_iters == [1, 6, 11, 16]
    assert iteration_to_accuracy(h, 0.85) == 11
    t = time_to_accuracy(h, 0.85)
    assert t == h.times[10]
    assert iteration_to_accuracy(h, 0.99) is None
    assert time_to_accuracy(h, 0.99) is None


def test_engine_history_records_eval_iters(small_graph):
    g = small_graph
    plan = TrainPlan(lr=0.3, n_iters=7, eval_every=3, seed=0)
    res = Trainer(g, _cfg(g), plan, source=SampledSource()).run()
    assert res.history.val_acc_iters == [1, 4, 7]


# ---------------------------------------------------------------------------
# HostStagingRing
# ---------------------------------------------------------------------------

def test_staging_ring_reuses_buffers():
    specs = [((2, 3), np.float32), ((2,), np.int32)]
    ring = HostStagingRing(2)
    s0 = ring.acquire()
    bufs0 = ring.buffers(s0, specs)
    assert [(b.shape, b.dtype) for b in bufs0] == [
        ((2, 3), np.dtype(np.float32)), ((2,), np.dtype(np.int32))]
    bufs0[0][:] = 7.0
    ring.release(s0)
    s1 = ring.acquire()
    s2 = ring.acquire()                      # both slots handed out
    assert {s1, s2} == {0, 1}
    # the recycled slot returns the SAME buffer objects (no realloc)
    assert ring.buffers(s0, specs)[0] is bufs0[0]
    # changed specs reallocate that slot's buffers
    bigger = [((4, 3), np.float32), ((2,), np.int32)]
    assert ring.buffers(s0, bigger)[0].shape == (4, 3)


def test_staging_ring_close_unblocks_acquire():
    ring = HostStagingRing(1)
    ring.acquire()                           # exhaust the ring
    ring.close()
    with pytest.raises(RuntimeError, match="closed"):
        ring.acquire()                       # would otherwise block
