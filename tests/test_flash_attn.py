"""Flash-attention Pallas kernel vs oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.ops import flash_attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,qb,kb,window", [
    (128, 32, 32, 0),
    (128, 32, 64, 0),
    (256, 64, 64, 64),    # sliding window banding
    (64, 64, 64, 0),      # single block
])
def test_flash_matches_oracle(s, qb, kb, window, dtype, rng):
    b, hq, hkv, d = 2, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    ref = flash_attention(q, k, v, window=window, use_kernel=False)
    ker = flash_attention(q, k, v, window=window, use_kernel=True,
                          interpret=True, q_block=qb, k_block=kb)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(ker, np.float32),
                               atol=tol, rtol=tol)


def test_flash_matches_model_chunked_path(rng):
    """Kernel agrees with the jnp chunked-causal path used by the model."""
    from repro.models.layers import chunked_causal_attention
    b, s, h, d = 1, 128, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    jnp_path = chunked_causal_attention(q, k, v, q_chunk=32)
    ker = flash_attention(q, k, v, use_kernel=True, interpret=True,
                          q_block=32, k_block=32)
    np.testing.assert_allclose(np.asarray(jnp_path), np.asarray(ker),
                               atol=3e-5, rtol=3e-5)
