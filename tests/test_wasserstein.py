"""Theorem 3 machinery: δ_i^{full-mini}, Sinkhorn OT, Δ(β, b) trends."""
import numpy as np
import pytest

from repro.core.wasserstein import (delta_full_mini, sinkhorn,
                                    wasserstein_delta)


def test_delta_full_mini_zero_at_full_fanout(small_graph):
    g = small_graph
    d = delta_full_mini(g, beta=g.d_max, nodes=g.train_nodes[:50])
    np.testing.assert_allclose(d, 0.0, atol=1e-10)


def test_delta_full_mini_decreasing_in_beta(small_graph):
    """Thm 3: δ_i^{full-mini} has an overall non-increasing trend in β."""
    g = small_graph
    nodes = g.train_nodes[:80]
    means = [delta_full_mini(g, beta=b, nodes=nodes, n_rounds=6).mean()
             for b in (1, 2, 4, 8, g.d_max)]
    # overall trend (allow tiny non-monotonic fluctuations, as the paper
    # itself notes)
    assert means[0] > means[2] > means[-1]
    assert means[-1] < 1e-9


def test_sinkhorn_marginals():
    rng = np.random.default_rng(0)
    cost = rng.random((4, 5))
    mu = rng.dirichlet(np.ones(4))
    nu = rng.dirichlet(np.ones(5))
    theta, total = sinkhorn(cost, mu, nu, eps=1e-2, iters=2000)
    np.testing.assert_allclose(theta.sum(1), mu, atol=1e-6)
    np.testing.assert_allclose(theta.sum(0), nu, atol=1e-6)
    assert total >= 0


def test_wasserstein_delta_monotone(small_graph):
    """Remark 4.1: Δ decreases as β or b grows."""
    g = small_graph
    d_beta = [wasserstein_delta(g, beta=b, b=64)["delta"]
              for b in (1, 4, g.d_max)]
    assert d_beta[0] > d_beta[1] > d_beta[2]
    d_b = [wasserstein_delta(g, beta=4, b=bb)["delta"]
           for bb in (16, 64, len(g.train_nodes))]
    assert d_b[0] >= d_b[1] >= d_b[2]
