"""Pallas kernel vs pure-jnp oracle: shape/dtype sweep (deliverable c)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.neighbor_agg.ops import neighbor_agg
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,b,k", [
    (64, 32, 8, 4),
    (128, 128, 16, 5),
    (50, 96, 4, 3),        # d padded to the 128 lane tile internally
    (200, 256, 32, 15),    # paper's recommended beta=15
    (16, 8, 16, 1),
])
def test_kernel_matches_oracle(n, d, b, k, dtype, rng):
    feats = jnp.asarray(rng.normal(size=(n, d)), dtype)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.random((b, k)) * (rng.random((b, k)) > 0.3), dtype)
    ref = neighbor_agg(feats, idx, w, use_kernel=False)
    ker = neighbor_agg(feats, idx, w, use_kernel=True, interpret=True,
                       d_tile=32 if d % 32 == 0 else 128)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(ker, np.float32),
                               atol=tol, rtol=tol)


def test_kernel_zero_weights_give_zero(rng):
    feats = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 32, (4, 6)), jnp.int32)
    w = jnp.zeros((4, 6), jnp.float32)
    out = neighbor_agg(feats, idx, w, use_kernel=True, interpret=True,
                       d_tile=64)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_kernel_is_gcn_aggregation(small_graph):
    """The kernel computes the paper's Ã-weighted aggregation: compare a
    full-graph GCN aggregation step against einsum on the ELL layout."""
    from repro.core.graph import to_ell
    g = small_graph
    idx, w, w_self = to_ell(g)
    feats = jnp.asarray(g.feats)
    ker = neighbor_agg(feats, jnp.asarray(idx), jnp.asarray(w),
                       use_kernel=True, interpret=True, d_tile=16)
    ref = neighbor_agg_ref(feats, jnp.asarray(idx), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-4)
