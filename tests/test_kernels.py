"""Pallas kernels vs pure-jnp oracle: shape/dtype sweep (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.neighbor_agg.ops import neighbor_agg
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref


@pytest.mark.parametrize("kernel", ["row", "tiled"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,b,k", [
    (64, 32, 8, 4),
    (128, 128, 16, 5),
    (50, 96, 4, 3),        # d padded to the 128 lane tile internally
    (200, 256, 32, 15),    # paper's recommended beta=15
    (16, 8, 16, 1),
])
def test_kernel_matches_oracle(n, d, b, k, dtype, kernel, rng):
    feats = jnp.asarray(rng.normal(size=(n, d)), dtype)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.random((b, k)) * (rng.random((b, k)) > 0.3), dtype)
    ref = neighbor_agg(feats, idx, w, use_kernel=False)
    ker = neighbor_agg(feats, idx, w, use_kernel=True, interpret=True,
                       kernel=kernel, d_tile=32 if d % 32 == 0 else 128)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(ker, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b_tile,k_slab", [(4, 2), (8, 4), (16, 1)])
def test_tiled_kernel_tile_shapes(b_tile, k_slab, rng):
    """Tile sizes that do NOT divide (B, K) force padded rows and padded
    K-slab edges — both must stay exact (zero-weight contributions)."""
    n, d, b, k = 100, 80, 13, 7
    feats = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.random((b, k)) * (rng.random((b, k)) > 0.4),
                    jnp.float32)
    ref = neighbor_agg(feats, idx, w, use_kernel=False)
    ker = neighbor_agg(feats, idx, w, use_kernel=True, interpret=True,
                       kernel="tiled", b_tile=b_tile, k_slab=k_slab)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kernel", ["row", "tiled"])
def test_kernel_zero_weights_give_zero(kernel, rng):
    feats = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 32, (4, 6)), jnp.int32)
    w = jnp.zeros((4, 6), jnp.float32)
    out = neighbor_agg(feats, idx, w, use_kernel=True, interpret=True,
                       kernel=kernel, d_tile=64)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("kernel", ["row", "tiled"])
def test_kernel_is_gcn_aggregation(small_graph, kernel):
    """The kernel computes the paper's Ã-weighted aggregation: compare a
    full-graph GCN aggregation step against einsum on the ELL layout."""
    from repro.core.graph import to_ell
    g = small_graph
    idx, w, w_self = to_ell(g)
    feats = jnp.asarray(g.feats)
    ker = neighbor_agg(feats, jnp.asarray(idx), jnp.asarray(w),
                       use_kernel=True, interpret=True, kernel=kernel,
                       d_tile=16)
    ref = neighbor_agg_ref(feats, jnp.asarray(idx), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("n,d,b,k", [
    (64, 32, 8, 4),
    (100, 80, 13, 7),      # B/D/K all padded
    (200, 256, 32, 15),
])
def test_tiled_kernel_fused_self_epilogue(n, d, b, k, rng):
    """The fused w_self·self_rows epilogue (accumulator init) matches
    aggregate-then-add to f32 tolerance, including padded tiles."""
    feats = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.random((b, k)) * (rng.random((b, k)) > 0.3),
                    jnp.float32)
    sr = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ws = jnp.asarray(rng.random(b), jnp.float32)
    ref = neighbor_agg(feats, idx, w, sr, ws)          # jnp oracle path
    ker = neighbor_agg(feats, idx, w, sr, ws, use_kernel=True,
                       interpret=True, kernel="tiled")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ker),
                               atol=1e-5, rtol=1e-5)


def test_fused_kernel_vjp_matches_jnp_grads(rng):
    """All four diff args of the fused kernel (feats, w, self_rows,
    w_self) must match jnp autodiff through the oracle path."""
    n, d, b, k = 60, 48, 12, 5
    feats = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.random((b, k)), jnp.float32)
    sr = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ws = jnp.asarray(rng.random(b), jnp.float32)

    def loss(f, ww, s, sw, use_kernel):
        out = neighbor_agg(f, idx, ww, s, sw, use_kernel=use_kernel,
                           interpret=True, kernel="tiled")
        return jnp.sum(out ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1, 2, 3))(feats, w, sr, ws, False)
    g_ker = jax.grad(loss, argnums=(0, 1, 2, 3))(feats, w, sr, ws, True)
    for a, b_ in zip(g_ref, g_ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-3, rtol=1e-3)


def test_kernel_custom_vjp_matches_jnp_grads(rng):
    """Training paths differentiate through the kernel: the custom VJP
    (scatter-add dfeats, gathered-dot dw) must match jnp autodiff."""
    n, d, b, k = 60, 48, 12, 5
    feats = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
    w = jnp.asarray(rng.random((b, k)), jnp.float32)

    def loss(f, ww, use_kernel):
        out = neighbor_agg(f, idx, ww, use_kernel=use_kernel,
                           interpret=True, kernel="tiled")
        return jnp.sum(out ** 2)

    gf_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(feats, w, False)
    gf_ker, gw_ker = jax.grad(loss, argnums=(0, 1))(feats, w, True)
    np.testing.assert_allclose(np.asarray(gf_ref), np.asarray(gf_ker),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gw_ref), np.asarray(gw_ker),
                               atol=1e-3, rtol=1e-3)
