"""METIS-free BFS partitioning + induced-subgraph ELL blocks
(core/partition.py — the host half of ClusterSource)."""
import numpy as np
import pytest

from repro.core.graph import Graph
from repro.core.partition import (bfs_partition, cluster_ell_blocks,
                                  partition_clusters)


def _path_graph():
    """0 - 1 - 2 undirected path, everything in the train split."""
    return Graph(n=3,
                 indptr=np.array([0, 1, 3, 4], np.int64),
                 indices=np.array([1, 0, 2, 1], np.int32),
                 feats=np.ones((3, 2), np.float32),
                 labels=np.array([0, 1, 0], np.int32),
                 train_mask=np.ones(3, bool),
                 val_mask=np.zeros(3, bool),
                 test_mask=np.zeros(3, bool))


def test_bfs_partition_covers_all_nodes_and_balances(small_graph):
    g = small_graph
    n_parts = 7
    part = bfs_partition(g, n_parts, seed=3)
    assert part.shape == (g.n,)
    assert part.min() >= 0 and part.max() < n_parts
    target = -(-g.n // n_parts)
    sizes = np.bincount(part)
    assert sizes.sum() == g.n
    assert sizes.max() <= target           # BFS growing respects budget
    assert sizes.min() >= 1


def test_bfs_partition_deterministic(small_graph):
    a = bfs_partition(small_graph, 5, seed=9)
    b = bfs_partition(small_graph, 5, seed=9)
    np.testing.assert_array_equal(a, b)


def test_bfs_partition_singletons_and_bounds(small_graph):
    g = small_graph
    part = bfs_partition(g, g.n + 50, seed=0)    # n_parts clamps to n
    assert np.bincount(part).max() == 1          # every part is one node
    with pytest.raises(ValueError, match="n_parts"):
        bfs_partition(g, 0)


def test_partition_clusters_sorted_nonempty(small_graph):
    part = bfs_partition(small_graph, 6, seed=1)
    clusters = partition_clusters(part)
    assert sum(len(c) for c in clusters) == small_graph.n
    for c in clusters:
        assert len(c) >= 1
        assert np.all(np.diff(c) > 0)            # sorted, unique


def test_cluster_ell_blocks_induced_weights():
    g = _path_graph()
    part = np.array([0, 0, 1], np.int32)         # {0, 1} and {2}
    blocks = cluster_ell_blocks(g, part)
    assert len(blocks.clusters) == 2
    # cluster {0, 1}: one induced edge, induced degree 1 on both ends
    idx0, w0, ws0 = blocks.idx[0], blocks.w[0], blocks.w_self[0]
    np.testing.assert_array_equal(idx0, [[1], [0]])      # local ids
    np.testing.assert_allclose(w0, 0.5)                  # 1/sqrt(2*2)
    np.testing.assert_allclose(ws0, 0.5)                 # 1/(1+1)
    # singleton cluster {2}: the 0 - 2 edge is cross-cluster -> dropped
    assert blocks.idx[1].shape == (1, 1)
    np.testing.assert_allclose(blocks.w[1], 0.0)
    np.testing.assert_allclose(blocks.w_self[1], 1.0)    # 1/(0+1)


def test_cluster_ell_blocks_local_ids_in_range(small_graph):
    part = bfs_partition(small_graph, 8, seed=2)
    blocks = cluster_ell_blocks(small_graph, part)
    for c, idx, w in zip(blocks.clusters, blocks.idx, blocks.w):
        assert idx.min() >= 0 and idx.max() < len(c)
        assert (w >= 0).all()
        # rows with any weight reference only in-cluster neighbors:
        # weights on padding columns are exactly zero
        assert w.shape == idx.shape
