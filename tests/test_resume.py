"""Exact resume: `Trainer.run(resume_from=...)` must continue a
checkpointed run bit-for-bit identical to the uninterrupted fixed-seed
run — History, params, final test accuracy — for full-graph GD and for
the prefetched sampled stream (whose rng state rides the checkpoint)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, save_checkpoint
from repro.configs.base import GNNConfig
from repro.core.engine import (ClusterSource, FullGraphSource,
                               SampledSource, Trainer, TrainPlan)


def _cfg(g, **kw):
    base = dict(name="resume", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=16,
                n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                batch_size=32, loss="ce")
    base.update(kw)
    return GNNConfig(**base)


def _params_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _assert_same_run(golden, resumed):
    assert resumed.history.losses == golden.history.losses
    assert resumed.history.val_accs == golden.history.val_accs
    assert resumed.history.val_acc_iters == golden.history.val_acc_iters
    assert resumed.history.full_losses == golden.history.full_losses
    assert (resumed.history.full_loss_iters
            == golden.history.full_loss_iters)
    assert (resumed.history.nodes_processed
            == golden.history.nodes_processed)
    assert _params_equal(resumed.params, golden.params)
    assert resumed.final_test_acc == golden.final_test_acc


@pytest.mark.parametrize("src_cls", [FullGraphSource, SampledSource,
                                     ClusterSource],
                         ids=["fullgraph", "sampled", "cluster"])
def test_resume_equals_uninterrupted_golden(small_graph, tmp_path,
                                            src_cls):
    g, cfg = small_graph, _cfg(small_graph)
    plan = TrainPlan(lr=0.3, n_iters=9, seed=0, eval_every=4,
                     track_full_loss_every=3, ckpt_every=3,
                     ckpt_dir=str(tmp_path / "golden"))
    golden = Trainer(g, cfg, plan, source=src_cls()).run()

    # interrupted run: stops after the it=3 checkpoint (n_iters=4 is a
    # stand-in for a kill at it=4 — the final save lands at it=3)
    d = str(tmp_path / "interrupted")
    short = dataclasses.replace(plan, n_iters=4, ckpt_dir=d)
    Trainer(g, cfg, short, source=src_cls()).run()
    assert latest_step(d) == 3

    full = dataclasses.replace(plan, ckpt_dir=d)
    resumed = Trainer(g, cfg, full, source=src_cls()).run(resume_from=d)
    _assert_same_run(golden, resumed)


def test_resume_prefetch_off_matches_prefetch_on(small_graph, tmp_path):
    """The sync sample-in-the-loop path checkpoints/resumes the same
    stream state as the prefetched path."""
    g, cfg = small_graph, _cfg(small_graph)
    plan = TrainPlan(lr=0.3, n_iters=8, seed=0, eval_every=100,
                     ckpt_every=3, ckpt_dir=str(tmp_path / "g"))
    golden = Trainer(g, cfg, plan,
                     source=SampledSource(prefetch=False)).run()
    d = str(tmp_path / "i")
    short = dataclasses.replace(plan, n_iters=4, ckpt_dir=d)
    Trainer(g, cfg, short, source=SampledSource(prefetch=False)).run()
    resumed = Trainer(g, cfg, dataclasses.replace(plan, ckpt_dir=d),
                      source=SampledSource(prefetch=True)
                      ).run(resume_from=d)
    assert resumed.history.losses == golden.history.losses
    assert _params_equal(resumed.params, golden.params)


def test_resume_missing_directory_raises(small_graph, tmp_path):
    g = small_graph
    plan = TrainPlan(lr=0.3, n_iters=4, seed=0)
    with pytest.raises(FileNotFoundError, match="no completed"):
        Trainer(g, _cfg(g), plan, source=FullGraphSource()).run(
            resume_from=str(tmp_path / "nope"))


def test_resume_params_only_checkpoint_rejected(small_graph, tmp_path):
    """Pre-fault-tolerance checkpoints (bare params, no engine_state)
    cannot be resumed exactly — clear error, not silent divergence."""
    g, cfg = small_graph, _cfg(small_graph)
    d = str(tmp_path)
    params = jax.tree.map(np.asarray, Trainer(
        g, cfg, TrainPlan(lr=0.3, n_iters=1, seed=0),
        source=FullGraphSource()).run().params)
    save_checkpoint(d, 0, {"params": params, "opt_state": {}},
                    {"loss": 1.0})
    plan = TrainPlan(lr=0.3, n_iters=4, seed=0)
    with pytest.raises(ValueError, match="engine_state"):
        Trainer(g, cfg, plan, source=FullGraphSource()).run(
            resume_from=d)


def test_resume_seed_mismatch_warns(small_graph, tmp_path):
    g, cfg = small_graph, _cfg(small_graph)
    d = str(tmp_path)
    plan = TrainPlan(lr=0.3, n_iters=4, seed=0, ckpt_every=3, ckpt_dir=d)
    Trainer(g, cfg, plan, source=SampledSource()).run()
    other = dataclasses.replace(plan, n_iters=6, seed=1)
    with pytest.warns(RuntimeWarning, match="seed"):
        Trainer(g, cfg, other, source=SampledSource()).run(resume_from=d)
