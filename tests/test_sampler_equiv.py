"""Equivalence of the vectorized CSR sampler with the seed per-node-loop
sampler (same DGL semantics), the prefetch pipeline, and the batch-tiled
kernel path of both GNN forwards."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.graph import neighbors_batch, to_ell
from repro.core.prefetch import Prefetcher
from repro.core.sampler import (expand_batch, gather_features,
                                sample_neighbors, sample_neighbors_loop)
from repro.core.trainer import train_minibatch


# ---------------------------------------------------------------------------
# vectorized sampler == loop sampler semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fanout", [1, 3, 8, 64])
def test_vectorized_sampler_semantics(small_graph, fanout):
    """Without-replacement; degree <= β keeps ALL neighbors; sampled ids
    are real neighbors; mask counts == min(deg, β) — identical semantics
    to `sample_neighbors_loop`."""
    g = small_graph
    rng = np.random.default_rng(5)
    src = rng.choice(g.n, size=256).astype(np.int32)
    nb, mk = sample_neighbors(rng, g, src, fanout)
    deg = g.degrees[src]
    assert nb.shape == (256, fanout) and mk.shape == (256, fanout)
    np.testing.assert_array_equal(mk.sum(-1), np.minimum(deg, fanout))
    for i, u in enumerate(src):
        real = set(g.neighbors(int(u)).tolist())
        sel = nb[i][mk[i]].tolist()
        assert len(set(sel)) == len(sel)             # without replacement
        assert set(sel) <= real                      # real neighbors only
        if deg[i] <= fanout:                         # keep-all regime
            assert set(sel) == real


def test_vectorized_sampler_respects_tree_shape(small_graph):
    g = small_graph
    rng = np.random.default_rng(5)
    src = rng.choice(g.n, size=(16, 5)).astype(np.int32)
    nb, mk = sample_neighbors(rng, g, src, 3)
    assert nb.shape == (16, 5, 3) and mk.shape == (16, 5, 3)


def test_expand_batch_weights_match_loop_sampler(small_graph):
    """ã^mini weights depend only on (mask, sampled-degree), so the two
    samplers produce identical weight STATISTICS: zero exactly on padding
    and w = 1/sqrt((D_in^mini+1)(d_out+1)) on sampled edges."""
    g = small_graph
    targets = g.train_nodes[:64]
    for sampler in (sample_neighbors, sample_neighbors_loop):
        fb = expand_batch(np.random.default_rng(0), g, targets, (5, 3),
                          neighbor_sampler=sampler)
        for d, (mk, w, nb) in enumerate(zip(fb.masks, fb.weights,
                                            fb.nodes[1:])):
            assert ((w > 0) == mk).all()
            samp_deg = mk.sum(-1, keepdims=True).astype(np.float32)
            rows = np.broadcast_to(samp_deg, nb.shape)
            expect = (1.0 / np.sqrt((rows + 1.0)
                                    * (g.degrees[nb] + 1.0))
                      ).astype(np.float32)
            np.testing.assert_allclose(w[mk], expect[mk], rtol=1e-5)


def test_sampler_uniformity(small_graph):
    """Each neighbor of a deg-d node appears with frequency ~ β/d."""
    g = small_graph
    u = int(np.argmax(g.degrees))
    deg = int(g.degrees[u])
    fanout = max(deg // 4, 2)
    counts = {int(v): 0 for v in g.neighbors(u)}
    rng = np.random.default_rng(11)
    trials = 3000
    for _ in range(trials):
        nb, mk = sample_neighbors(rng, g, np.array([u], np.int32), fanout)
        for v in nb[0][mk[0]]:
            counts[int(v)] += 1
    freq = np.array(list(counts.values()), np.float64)
    expect = trials * fanout / deg
    assert np.abs(freq - expect).max() < 0.25 * expect


def test_edgeless_graph_matches_loop_sampler():
    """Zero-edge graph: both samplers (and to_ell) must return all-padding
    instead of crashing on the empty CSR indices array."""
    from repro.core.graph import Graph
    n = 8
    g = Graph(n=n, indptr=np.zeros(n + 1, np.int64),
              indices=np.zeros(0, np.int32),
              feats=np.zeros((n, 4), np.float32),
              labels=np.zeros(n, np.int32),
              train_mask=np.ones(n, bool), val_mask=np.zeros(n, bool),
              test_mask=np.zeros(n, bool))
    src = np.arange(n, dtype=np.int32)
    rng = np.random.default_rng(0)
    nb_v, mk_v = sample_neighbors(rng, g, src, 3)
    nb_l, mk_l = sample_neighbors_loop(rng, g, src, 3)
    np.testing.assert_array_equal(nb_v, nb_l)
    np.testing.assert_array_equal(mk_v, mk_l)
    assert not mk_v.any()
    idx, w, w_self = to_ell(g, max_deg=2)
    assert (w == 0).all() and (idx == 0).all()


def test_neighbors_batch_matches_csr(small_graph):
    g = small_graph
    rows = np.arange(0, g.n, 7, dtype=np.int64)
    nb, valid = neighbors_batch(g, rows)
    for i, u in enumerate(rows):
        np.testing.assert_array_equal(nb[i][valid[i]], g.neighbors(int(u)))


# ---------------------------------------------------------------------------
# prefetch pipeline
# ---------------------------------------------------------------------------

def test_prefetcher_reproduces_sync_batches(small_graph):
    """The background pipeline must consume the SAME rng stream as the
    synchronous sample-in-the-loop path (bitwise-identical batches)."""
    g = small_graph
    from repro.core.sampler import sample_batch
    rng = np.random.default_rng(9)
    want = [sample_batch(rng, g, 32, (5, 3)) for _ in range(4)]
    with Prefetcher(g, 32, (5, 3), seed=9, n_batches=4) as pf:
        got = [pf.next() for _ in range(4)]
        with pytest.raises(StopIteration):
            pf.next()
    for (fb, feats), ref in zip(got, want):
        for a, b in zip(fb.nodes, ref.nodes):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(fb.weights, ref.weights):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(fb.labels, ref.labels)
        for f, ids in zip(feats, ref.nodes):
            np.testing.assert_array_equal(
                f, g.feats[ids.reshape(-1)].reshape(ids.shape + (-1,)))


def test_train_minibatch_prefetch_equals_sync(small_graph):
    g = small_graph
    cfg = GNNConfig(name="t", model="graphsage", n_nodes=g.n,
                    feat_dim=g.feats.shape[1], hidden=16,
                    n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                    batch_size=32, loss="ce")
    r_pf = train_minibatch(g, cfg, lr=0.3, n_iters=6, prefetch=True)
    r_sync = train_minibatch(g, cfg, lr=0.3, n_iters=6, prefetch=False)
    np.testing.assert_allclose(r_pf.history.losses, r_sync.history.losses,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# kernelized forwards == einsum forwards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "graphsage"])
def test_use_agg_kernel_matches_einsum_forwards(small_graph, model):
    g = small_graph
    cfg = GNNConfig(name="t", model=model, n_nodes=g.n,
                    feat_dim=g.feats.shape[1], hidden=32,
                    n_classes=g.n_classes, n_layers=2, fanout=(5, 3),
                    batch_size=32, loss="ce")
    cfg_k = dataclasses.replace(cfg, use_agg_kernel=True)
    params = G.init_gnn(jax.random.key(0), cfg, g.feats.shape[1])
    idx, w, ws = to_ell(g)
    args = [jnp.asarray(x) for x in (g.feats, idx, w, ws)]
    full = G.full_graph_forward(params, cfg, *args)
    full_k = G.full_graph_forward(params, cfg_k, *args)
    np.testing.assert_allclose(np.asarray(full_k), np.asarray(full),
                               atol=1e-4, rtol=1e-4)

    fb = expand_batch(np.random.default_rng(0), g, g.train_nodes[:32],
                      (5, 3))
    feats = [jnp.asarray(f) for f in gather_features(g, fb)]
    masks = [jnp.asarray(m.astype(np.float32)) for m in fb.masks]
    wts = [jnp.asarray(x) for x in fb.weights]
    sw = [jnp.asarray(x) for x in fb.self_w]
    mini = G.minibatch_forward(params, cfg, feats, masks, wts, sw)
    mini_k = G.minibatch_forward(params, cfg_k, feats, masks, wts, sw)
    np.testing.assert_allclose(np.asarray(mini_k), np.asarray(mini),
                               atol=1e-4, rtol=1e-4)


def test_use_agg_kernel_gradients_match(small_graph):
    g = small_graph
    cfg = GNNConfig(name="t", model="gcn", n_nodes=g.n,
                    feat_dim=g.feats.shape[1], hidden=16,
                    n_classes=g.n_classes, n_layers=1, fanout=(4,),
                    batch_size=16, loss="ce")
    cfg_k = dataclasses.replace(cfg, use_agg_kernel=True)
    params = G.init_gnn(jax.random.key(1), cfg, g.feats.shape[1])
    idx, w, ws = to_ell(g)
    args = [jnp.asarray(x) for x in (g.feats, idx, w, ws)]

    def loss(p, c):
        return jnp.sum(G.full_graph_forward(p, c, *args) ** 2)

    g_ref = jax.grad(lambda p: loss(p, cfg))(params)
    g_ker = jax.grad(lambda p: loss(p, cfg_k))(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_ker)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-3)
