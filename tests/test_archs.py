"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED same-family config, runs one forward/train step on CPU with shape +
finiteness assertions, plus a prefill->decode round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models import model as M

LM_ARCHS = [a for a in list_archs()
            if get_config(a).family != "gnn"]


def _batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    st = s - (cfg.frontend_seq or 0)
    out = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, st)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, st)),
                              jnp.int32),
    }
    if cfg.frontend_seq:
        out["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_seq, cfg.d_model)), jnp.float32)
    if cfg.n_enc_layers:
        out["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return out


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, smoke=True)
            params = M.init_model(jax.random.key(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: M.forward_train(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert bool(jnp.isfinite(metrics["loss"]))
    # loss should start near ln(vocab) for random init
    assert float(metrics["loss"]) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_no_nans(arch, arch_state):
    from repro.models import steps as S
    cfg, params = arch_state(arch)
    opt, step = S.make_train_step(cfg)
    opt_state = opt.init(params)
    batch = _batch(cfg)
    p2, o2, metrics = jax.jit(step)(params, opt_state, batch)
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch
    assert bool(jnp.isfinite(metrics["loss"]))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_roundtrip(arch, arch_state):
    cfg, params = arch_state(arch)
    batch = _batch(cfg)
    last, cache = jax.jit(lambda p, b: M.prefill(p, cfg, b))(params, batch)
    assert last.shape == (2, M._vp(cfg))
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    lg, cache2 = jax.jit(
        lambda p, c, t: M.decode_step(p, cfg, c, t))(params, cache, tok)
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1
    # vocab padding must stay masked
    if M._vp(cfg) != cfg.vocab_size:
        assert float(jnp.max(lg[:, cfg.vocab_size:])) < -1e20


def test_microbatched_train_step_matches_plain():
    from repro.models import steps as S
    cfg = get_config("granite-3-2b", smoke=True)
    params = M.init_model(jax.random.key(0), cfg)
    batch = _batch(cfg, b=4)
    opt1, s1 = S.make_train_step(cfg, microbatches=1)
    opt2, s2 = S.make_train_step(cfg, microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt1.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt2.init(params), batch)
    # same global batch -> same mean loss and near-identical update
    assert np.isclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3
