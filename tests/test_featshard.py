"""Sharded feature tables + degree-ordered hot cache (PR 8).

Equivalence contract (extends the PR 5 sharded-kernel pattern):
- on a 1-DEVICE mesh the featshard op is BIT-identical to the unsharded
  tiled kernel — forward and gradients — for every cache size
  (C = auto / 0 / n), fused and unfused;
- on a 4-DEVICE CPU mesh (own subprocess) it matches the einsum
  reference fwd + grads (dw compared where w != 0: zero-weight remote
  refs are excluded from the serve set, so their never-consumed dw
  entries differ from the dense reference by design), the dfeats
  scatter-add VJP equals the replicated path's psum VJP, both sharded
  sources train loss-equal to the replicated layout, and the per-device
  table bytes obey the n·d/S + C·d bound;
- the host plan build is pure numpy and testable without a mesh: Zipf
  degree distributions give the hot cache a high hit rate, C=0 turns
  every non-local reference into a miss, C=n eliminates misses.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as sh
from repro.configs.base import GNNConfig
from repro.core.engine import (ShardedFullGraphSource,
                               ShardedSampledSource, Trainer, TrainPlan)
from repro.core.featcache import DegreeHotRowCache, LRURowCache
from repro.data import make_sbm_graph
from repro.kernels.neighbor_agg.featshard import (_plan_arrays,
                                                  resolve_cache_rows)
from repro.kernels.neighbor_agg.ops import (build_featshard_plan,
                                            neighbor_agg,
                                            neighbor_agg_featshard)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KW = dict(interpret=True, d_tile=8, b_tile=4, k_slab=2)


def _cfg(g, **kw):
    base = dict(name="fs", model="gcn", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=16,
                n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                batch_size=32, loss="ce", use_agg_kernel=True,
                agg_interpret=True, agg_b_tile=4, agg_d_tile=8,
                agg_k_slab=2)
    base.update(kw)
    return GNNConfig(**base)


@pytest.fixture(scope="module")
def graph():
    return make_sbm_graph(n=120, n_classes=4, avg_degree=8, feat_dim=16,
                          seed=7)


# ---------------------------------------------------------------------------
# Host plan build (pure numpy, no mesh required)
# ---------------------------------------------------------------------------

def _zipf_ell(n=256, k=8, seed=0, a=1.3):
    """ELL whose column ids follow a Zipf(a) rank distribution over a
    degree-sorted id space — the power-law regime the hot cache targets."""
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(a, size=(n, k)) - 1, n - 1)
    idx = ranks.astype(np.int32)                # id == popularity rank
    w = rng.normal(size=(n, k)).astype(np.float32)
    degrees = np.bincount(idx.reshape(-1), minlength=n)
    return idx, w, degrees


def test_plan_hot_cache_hit_rate_on_zipf_degrees():
    idx, w, degrees = _zipf_ell()
    host = _plan_arrays(idx, w, degrees, n_shards=4,
                        cache_rows=-1)          # auto: C = n // 8 = 32
    st = host["stats"]
    assert st["feat_cache_rows"] == 32
    # top-32-of-256 under Zipf(1.3) catches the bulk of references; the
    # rest splits between local hits and misses
    assert st["feat_cache_hit_rate"] >= 0.75, st
    # the cache must beat the no-cache layout by a wide margin
    st0 = _plan_arrays(idx, w, degrees, n_shards=4,
                       cache_rows=0)["stats"]
    assert st["feat_cache_hit_rate"] >= st0["feat_cache_hit_rate"] + 0.3
    # accounting is exhaustive: every nonzero reference is classified
    nz = int((w != 0).sum())
    assert (st["feat_cache_hot_hits"] + st["feat_cache_local_hits"]
            + st["feat_cache_misses"]) == nz


def test_plan_cache_size_zero_all_nonlocal_miss():
    idx, w, degrees = _zipf_ell(n=64, k=4, seed=1)
    host = _plan_arrays(idx, w, degrees, n_shards=4, cache_rows=0)
    st = host["stats"]
    assert host["C"] == 0 and st["feat_cache_hot_hits"] == 0
    # with no hot set, every nonzero non-local reference is a miss
    owner = np.arange(64) // 16
    expect = int(((w != 0)
                  & (owner[idx] != owner[:, None])).sum())
    assert st["feat_cache_misses"] == expect
    assert host["M"] > 0


def test_plan_cache_covers_all_no_miss():
    idx, w, degrees = _zipf_ell(n=64, k=4, seed=2)
    host = _plan_arrays(idx, w, degrees, n_shards=4, cache_rows=64)
    st = host["stats"]
    assert host["M"] == 0                        # empty serve set
    assert st["feat_cache_misses"] == 0
    assert st["feat_cache_hit_rate"] == 1.0


def test_plan_rejects_indivisible_rows():
    idx, w, degrees = _zipf_ell(n=66, k=4, seed=3)
    with pytest.raises(ValueError, match="divide"):
        _plan_arrays(idx, w, degrees, n_shards=4, cache_rows=0)


def test_resolve_cache_rows():
    assert resolve_cache_rows(-1, 256) == 32     # auto n // 8
    assert resolve_cache_rows(None, 256) == 32
    assert resolve_cache_rows(-1, 4) == 1        # at least 1
    assert resolve_cache_rows(0, 256) == 0       # off
    assert resolve_cache_rows(1000, 256) == 256  # clamped to n


def test_table_bytes_bound_host_arithmetic():
    """ISSUE 8 acceptance bound, host side: resident bytes per device
    are (n/S + C)·d·itemsize — never the replicated n·d."""
    idx, w, degrees = _zipf_ell(n=256, k=8)
    d, item = 32, 4
    host = _plan_arrays(idx, w, degrees, n_shards=4, cache_rows=-1)
    per_dev = (host["n_loc"] + host["C"]) * d * item
    assert per_dev <= 256 * d * item // 4 + host["C"] * d * item
    assert per_dev < 256 * d * item              # strictly sub-replicated


# ---------------------------------------------------------------------------
# Host LRU / degree caches (sampled sources' accounting twin)
# ---------------------------------------------------------------------------

def test_lru_cache_hits_misses_and_eviction():
    c = LRURowCache(capacity=2, row_bytes=8)
    assert c.lookup([1, 2]) == 2                 # cold: both miss
    assert c.lookup([1, 2]) == 0                 # warm: both hit
    c.lookup([3])                                # evicts LRU id 1
    assert c.lookup([1]) == 1                    # 1 was evicted
    st = c.stats()
    assert st["feat_cache_hits"] == 2 and st["feat_cache_misses"] == 4
    assert st["feat_remote_gather_bytes"] == 4 * 8
    assert 0.0 < st["feat_cache_hit_rate"] < 1.0


def test_lru_cache_capacity_zero_all_miss():
    c = LRURowCache(capacity=0, row_bytes=4)
    assert c.lookup([5, 5, 5]) == 3              # no cache: every ref
    st = c.stats()
    assert st["feat_cache_hits"] == 0
    assert st["feat_cache_hit_rate"] == 0.0


def test_lru_duplicate_ids_hit_after_first_touch():
    c = LRURowCache(capacity=4)
    assert c.lookup([7, 7, 7]) == 1              # first touch misses


def test_degree_hot_cache_membership():
    c = DegreeHotRowCache(degrees=[5, 1, 9, 3], capacity=2)
    c.lookup([2, 0, 1, 3])                       # hot set = {2, 0}
    st = c.stats()
    assert st["feat_cache_hits"] == 2 and st["feat_cache_misses"] == 2


# ---------------------------------------------------------------------------
# Op level: 1-device mesh == unsharded tiled kernel, bit for bit
# ---------------------------------------------------------------------------

def _operands(fused, n=40, d=12, k=5, seed=0):
    """Square full-graph operands: table rows == ELL rows (n_pad = n)."""
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    idx = rng.integers(0, n, size=(n, k)).astype(np.int32)
    w = rng.normal(size=(n, k)).astype(np.float32)
    w[rng.random(size=w.shape) < 0.15] = 0.0     # zero-weight padding
    degrees = np.bincount(idx.reshape(-1), minlength=n)
    extra = ()
    if fused:
        extra = (jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)),
                 jnp.asarray(rng.normal(size=(n,)).astype(np.float32)))
    return feats, idx, jnp.asarray(w), degrees, extra


@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("cache_rows", [-1, 0, 40])
def test_featshard_op_bit_equal_on_one_device_mesh(fused, cache_rows):
    feats, idx, w, degrees, extra = _operands(fused)
    mesh = sh.node_mesh(1)
    plan = build_featshard_plan(np.asarray(idx), np.asarray(w), degrees,
                                mesh, cache_rows=cache_rows)
    base = neighbor_agg(feats, jnp.asarray(idx), w, *extra,
                        use_kernel=True, kernel="tiled", **KW)
    fsout = neighbor_agg_featshard(feats, w, plan, *extra, **KW)
    assert np.array_equal(np.asarray(base), np.asarray(fsout))
    # grads bit-equal too: feats, w (+ self_rows, w_self)
    fdiff = (0, 1) + ((2, 3) if fused else ())
    gb = jax.grad(lambda *a: (neighbor_agg(
        a[0], jnp.asarray(idx), *a[1:], use_kernel=True, kernel="tiled",
        **KW) ** 2).sum(), argnums=fdiff)(feats, w, *extra)
    gs = jax.grad(lambda *a: (neighbor_agg_featshard(
        a[0], a[1], plan, *a[2:], **KW) ** 2).sum(),
        argnums=fdiff)(feats, w, *extra)
    for a, b in zip(gb, gs):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_featshard_rejects_mismatched_operands():
    feats, idx, w, degrees, _ = _operands(False)
    mesh = sh.node_mesh(1)
    plan = build_featshard_plan(np.asarray(idx), np.asarray(w), degrees,
                                mesh, cache_rows=0)
    with pytest.raises(ValueError, match="rebuild the plan"):
        neighbor_agg_featshard(feats[:20], w, plan, **KW)
    with pytest.raises(ValueError, match="rebuild the plan"):
        neighbor_agg_featshard(feats, w[:, :3], plan, **KW)


# ---------------------------------------------------------------------------
# Engine level: feats_layout="sharded", 1-device mesh bit-equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "graphsage"])
def test_featshard_fullgraph_bit_equal_one_device(graph, model):
    cfg = _cfg(graph, model=model)
    fscfg = dataclasses.replace(cfg, feats_layout="sharded",
                                feat_cache_rows=-1)
    plan = TrainPlan(lr=0.3, n_iters=4, eval_every=2, seed=0)
    r1 = Trainer(graph, cfg, plan, source=ShardedFullGraphSource()).run()
    t = Trainer(graph, fscfg, plan, source=ShardedFullGraphSource())
    r2 = t.run()
    assert r1.history.losses == r2.history.losses
    assert r1.history.val_accs == r2.history.val_accs
    assert r1.final_test_acc == r2.final_test_acc
    # the bind-time accounting surfaced through History.counters
    c = r2.history.counters
    assert c["feat_cache_hit_rate"] == 1.0       # 1 device: no misses
    assert c["feat_table_bytes_per_device"] > 0
    assert r1.history.counters == {}             # replicated: no counters


def test_featshard_sampled_source_lru_counters(graph):
    cfg = _cfg(graph, feats_layout="sharded", feat_cache_rows=16)
    plan = TrainPlan(lr=0.3, n_iters=3, eval_every=100, seed=0)
    t = Trainer(graph, cfg, plan,
                source=ShardedSampledSource(batch_size=32))
    res = t.run()
    c = res.history.counters
    assert c["feat_cache_rows"] == 16
    assert c["feat_cache_hits"] + c["feat_cache_misses"] > 0
    assert 0.0 <= c["feat_cache_hit_rate"] <= 1.0
    assert c["feat_remote_gather_bytes"] == (c["feat_cache_misses"]
                                             * graph.feats.shape[1] * 4)


def test_history_counters_roundtrip_through_checkpoint_dict():
    from repro.core.metrics import History
    h = History()
    h.counters["feat_cache_hit_rate"] = 0.75
    h.record(1.0)
    h2 = History.from_dict(h.to_dict())
    assert h2.counters == h.counters


# ---------------------------------------------------------------------------
# Inference: featshard layer-wise pass == replicated forward, 1 device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "graphsage"])
def test_featshard_inference_layers_match_forward(graph, model):
    from repro.core.gnn import full_graph_forward, init_gnn
    from repro.core.graph import to_ell
    from repro.core.inference import layerwise_embeddings

    cfg = _cfg(graph, model=model, feats_layout="sharded",
               feat_cache_rows=-1)
    params = init_gnn(jax.random.PRNGKey(0), cfg, graph.feats.shape[1])
    idx, w, w_self = to_ell(graph)
    rcfg = dataclasses.replace(cfg, feats_layout="replicated")
    _, ref_layers = full_graph_forward(
        params, rcfg, jnp.asarray(graph.feats), jnp.asarray(idx),
        jnp.asarray(w), jnp.asarray(w_self), return_layers=True)
    run = layerwise_embeddings(params, cfg, graph, mesh=sh.node_mesh())
    assert run.stats["feat_table_bytes_per_device"] > 0
    for a, b in zip(run.layers, ref_layers):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 4-device CPU mesh (subprocess): sharded table vs replicated/einsum
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro import sharding as sh
from repro.data import make_sbm_graph
from repro.configs.base import GNNConfig
from repro.core.engine import (ShardedFullGraphSource,
                               ShardedSampledSource, Trainer, TrainPlan)
from repro.kernels.neighbor_agg.ops import (build_featshard_plan,
                                            neighbor_agg_featshard,
                                            neighbor_agg_sharded)

mesh = sh.node_mesh()
KW = dict(interpret=True, d_tile=8, b_tile=4, k_slab=2)

# -- op level: fwd + grads vs the einsum reference, C auto and 0 ------------
rng = np.random.default_rng(0)
N, D, K = 40, 12, 5                      # N divides the 4 shards
feats = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
idx = rng.integers(0, N, size=(N, K)).astype(np.int32)
w_h = rng.normal(size=(N, K)).astype(np.float32)
w_h[rng.random(size=w_h.shape) < 0.15] = 0.0
w = jnp.asarray(w_h)
degrees = np.bincount(idx.reshape(-1), minlength=N)
jidx = jnp.asarray(idx)

def ref(f, ww):
    return jnp.einsum("bk,bkd->bd", ww, jnp.take(f, jidx, axis=0))

nzmask = w_h != 0
for C in (-1, 0, N):
    plan = build_featshard_plan(idx, w_h, degrees, mesh, cache_rows=C)
    out = neighbor_agg_featshard(feats, w, plan, **KW)
    np.testing.assert_allclose(out, ref(feats, w), rtol=1e-5, atol=1e-5)
    gf, gw = jax.grad(lambda f, ww: (neighbor_agg_featshard(
        f, ww, plan, **KW) ** 2).sum(), argnums=(0, 1))(feats, w)
    rf, rw = jax.grad(lambda f, ww: (ref(f, ww) ** 2).sum(),
                      argnums=(0, 1))(feats, w)
    # dfeats: the scatter-add VJP must equal the dense reference
    np.testing.assert_allclose(gf, rf, rtol=1e-4, atol=1e-5)
    # dw compared where w != 0: zero-weight REMOTE refs are excluded
    # from the serve set by design, so their never-consumed dw entries
    # legitimately differ from the dense reference
    np.testing.assert_allclose(np.asarray(gw)[nzmask],
                               np.asarray(rw)[nzmask],
                               rtol=1e-4, atol=1e-5)
    # ... and against the replicated-table psum VJP (PR 5 path): the
    # owner-scatter dfeats must agree with psum-of-replicated exactly
    # up to float tolerance
    sf = jax.grad(lambda f: (neighbor_agg_sharded(
        f, jidx, w, mesh=mesh, **KW) ** 2).sum())(feats)
    np.testing.assert_allclose(gf, sf, rtol=1e-4, atol=1e-5)
    # acceptance bound: per-device resident bytes <= n*d/S + C*d
    Ceff = plan.C
    assert plan.table_bytes_per_device(D) <= (N * D * 4) // 4 + Ceff * D * 4
print("FEATSHARD_OP_OK", flush=True)

# -- engine level: feats_layout sharded vs replicated, both sources ---------
g = make_sbm_graph(n=120, n_classes=4, avg_degree=8, feat_dim=16, seed=5)
base = GNNConfig(name="fsmd", model="gcn", n_nodes=g.n, feat_dim=16,
                 hidden=16, n_classes=g.n_classes, n_layers=2,
                 fanout=(4, 3), batch_size=32, loss="ce",
                 use_agg_kernel=True, agg_interpret=True, agg_b_tile=4,
                 agg_d_tile=8, agg_k_slab=2)
plan = TrainPlan(lr=0.3, n_iters=3, eval_every=2, seed=0)
for model in ("gcn", "graphsage"):
    rcfg = dataclasses.replace(base, model=model)
    fcfg = dataclasses.replace(rcfg, feats_layout="sharded",
                               feat_cache_rows=-1)
    r_r = Trainer(g, rcfg, plan, source=ShardedFullGraphSource()).run()
    t = Trainer(g, fcfg, plan, source=ShardedFullGraphSource())
    r_f = t.run()
    np.testing.assert_allclose(r_r.history.losses, r_f.history.losses,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(r_r.final_test_acc, r_f.final_test_acc)
    c = r_f.history.counters
    assert 0.0 <= c["feat_cache_hit_rate"] <= 1.0, c
    assert c["feat_cache_misses"] > 0            # 4 shards: real misses
    # acceptance: per-device source-table bytes <= n*d/S + C*d
    item = g.feats.dtype.itemsize
    n_pad = t.source.feats_plan.n_pad
    Ceff = t.source.feats_plan.C
    bound = (n_pad * 16 * item) // 4 + Ceff * 16 * item
    assert c["feat_table_bytes_per_device"] <= bound, (c, bound)
    assert c["feat_remote_gather_bytes"] > 0
print("FEATSHARD_ENGINE_OK", flush=True)

# -- sampled source: LRU accounting on a 4-device mesh ----------------------
scfg = dataclasses.replace(base, feats_layout="sharded",
                           feat_cache_rows=16)
res = Trainer(g, scfg, plan,
              source=ShardedSampledSource(batch_size=32)).run()
c = res.history.counters
assert c["feat_cache_rows"] == 16 and c["feat_cache_misses"] > 0
print("FEATSHARD_LRU_OK", flush=True)

# -- inference: featshard layer-wise pass vs replicated forward -------------
from repro.core.gnn import full_graph_forward, init_gnn
from repro.core.graph import to_ell
from repro.core.inference import layerwise_embeddings
icfg = dataclasses.replace(base, feats_layout="sharded")
params = init_gnn(jax.random.PRNGKey(0), icfg, 16)
idx2, w2, ws2 = to_ell(g)
_, ref_layers = full_graph_forward(
    params, dataclasses.replace(icfg, feats_layout="replicated"),
    jnp.asarray(g.feats), jnp.asarray(idx2), jnp.asarray(w2),
    jnp.asarray(ws2), return_layers=True)
run = layerwise_embeddings(params, icfg, g, mesh=mesh)
for a, b in zip(run.layers, ref_layers):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
assert run.stats["feat_table_bytes_per_device"] > 0
print("FEATSHARD_INFER_OK", flush=True)
"""


def test_featshard_on_multidevice_cpu_mesh():
    """4 virtual CPU devices (own process: the XLA flag must be set
    before jax initializes): sharded-table op == einsum fwd/grads with
    the scatter-add dfeats matching the replicated path's psum, engine
    runs loss-equal to the replicated layout for both sharded sources,
    the per-device byte bound holds, and featshard inference matches
    the replicated forward."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for sentinel in ("FEATSHARD_OP_OK", "FEATSHARD_ENGINE_OK",
                     "FEATSHARD_LRU_OK", "FEATSHARD_INFER_OK"):
        assert sentinel in out.stdout, out.stdout
