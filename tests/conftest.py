import os

# Tests see the single real CPU device (the 512-device flag belongs ONLY to
# launch/dryrun.py).  Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_graph():
    from repro.data import make_sbm_graph
    return make_sbm_graph(n=300, n_classes=4, avg_degree=10, feat_dim=16,
                          seed=1)
