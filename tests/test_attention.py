"""Attention correctness: the chunked online path vs a direct masked
oracle; sliding-window semantics; decode == full-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import layers as L
from repro.models import model as M


def _direct_causal(q, k, v, window=0):
    b, s, h, d = q.shape
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]
    if window:
        mask = mask & (pos[None, :] > pos[:, None] - window)
    scale = 1.0 / np.sqrt(d)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(scores, -1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("window", [0, 16, 48])
@pytest.mark.parametrize("s,qc", [(64, 16), (128, 32)])
def test_chunked_matches_direct(window, s, qc, rng):
    b, h, d = 2, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    got = L.chunked_causal_attention(q, k, v, q_chunk=qc, window=window)
    want = _direct_causal(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-12b",
                                  "mamba2-130m", "zamba2-7b",
                                  "llama4-scout-17b-a16e"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode logits must match the full forward pass —
    covers global attention, sliding-window ring caches, SSD state decode,
    hybrid shared-attn, and MoE decode routing."""
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:
        # MoE capacity dropping is batch-shape-dependent (a group of 1
        # decode token never overflows; a 32-token train group can), so
        # teacher-forced equivalence only holds in the no-drop regime.
        cfg = cfg.__class__(**{**cfg.__dict__, "capacity_factor": 8.0})
    params = M.init_model(jax.random.key(1), cfg)
    b, s, extra = 2, 64, 32          # s and s+extra are q_chunk multiples
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s + extra)),
                       jnp.int32)

    # reference: full forward logits at every position
    x = M.embed_tokens(params, cfg, toks)
    hid, _, _ = M.backbone(params, cfg, x, jnp.arange(s + extra))
    ref_logits = M.logits_fn(params, cfg, hid)

    last, cache = jax.jit(
        lambda p, bb: M.prefill(p, cfg, bb, max_len=s + extra))(
            params, {"tokens": toks[:, :s]})
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(ref_logits[:, s - 1]),
                               atol=2e-3, rtol=2e-3)
    dec = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))
    for t in range(extra):
        lg, cache = dec(params, cache, toks[:, s + t:s + t + 1])
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(ref_logits[:, s + t]),
            atol=8e-3, rtol=8e-3, err_msg=f"{arch} step {t}")


def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 32)), jnp.float32)
    y = L.apply_rope(x, jnp.arange(8), 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(x[:, :1]), np.asarray(y[:, :1]),
                               atol=1e-6)


def test_gqa_expand():
    k = jnp.arange(2 * 4 * 2 * 3, dtype=jnp.float32).reshape(2, 4, 2, 3)
    ke = L._expand_kv(k, 6)
    assert ke.shape == (2, 4, 6, 3)
    # groups of 3 query heads share one kv head
    np.testing.assert_array_equal(np.asarray(ke[:, :, 0]),
                                  np.asarray(ke[:, :, 2]))
    np.testing.assert_array_equal(np.asarray(ke[:, :, 3]),
                                  np.asarray(ke[:, :, 5]))
