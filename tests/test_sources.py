"""Scenario-diverse BatchSources (cluster / importance / sharded
mini-batch) + the hardened sampling/boundary layer: fixed-seed
determinism per source, 1-device bit-equality for the sharded
mini-batch, boundary paths (b == n_train, b > n_train, single-node
clusters, beta > d_max, unnormalized importance scores), and the
regression tests for the max_deg-truthiness, empty-train-split and
stuck-Prefetcher satellites."""
import dataclasses
import json
import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core.engine import (ClusterSource, FullGraphSource,
                               ImportanceSampledSource, SampledSource,
                               ShardedFullGraphSource,
                               ShardedSampledSource, Trainer, TrainPlan,
                               _device_ell)
from repro.core.experiment import make_source, run_experiment, sweep
from repro.core.gnn import gnn_loss
from repro.core.graph import to_ell
from repro.core.prefetch import Prefetcher
from repro.core.sampler import expand_batch, sample_batch
from repro.data import make_sbm_graph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(g, **kw):
    base = dict(name="src", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=32,
                n_classes=g.n_classes, n_layers=2, fanout=(5, 3),
                batch_size=64, loss="ce")
    base.update(kw)
    return GNNConfig(**base)


@pytest.fixture(scope="module")
def graph():
    return make_sbm_graph(n=240, n_classes=4, avg_degree=8, feat_dim=16,
                          seed=31)


def _no_train(g):
    empty = np.zeros(g.n, bool)
    return dataclasses.replace(g, train_mask=empty)


# ---------------------------------------------------------------------------
# ClusterSource
# ---------------------------------------------------------------------------

def test_cluster_source_trains_and_is_deterministic(graph):
    cfg = _cfg(graph)
    plan = TrainPlan(lr=0.3, n_iters=6, eval_every=3, seed=0)
    r1 = Trainer(graph, cfg, plan, source=ClusterSource()).run()
    r2 = Trainer(graph, cfg, plan, source=ClusterSource()).run()
    assert r1.history.losses == r2.history.losses
    assert r1.history.val_accs == r2.history.val_accs
    assert r1.final_test_acc == r2.final_test_acc
    assert all(np.isfinite(r1.history.losses))
    assert all(n >= 1 for n in r1.history.nodes_processed)


def test_cluster_source_compiles_one_fixed_shape(graph):
    cfg = _cfg(graph)
    plan = TrainPlan(lr=0.3, n_iters=5, seed=0)
    t = Trainer(graph, cfg, plan, source=ClusterSource())
    t.run()
    assert t._step._cache_size() == 1          # padded (m_max, K) shape


def test_cluster_source_single_node_clusters(graph):
    """n_parts = n degenerates to single-node clusters: every batch is k
    isolated nodes with w_self = 1 — the boundary the induced-degree
    weights must survive."""
    src = ClusterSource(clusters_per_batch=4, n_parts=graph.n)
    plan = TrainPlan(lr=0.3, n_iters=4, seed=0)
    res = Trainer(graph, _cfg(graph), plan, source=src).run()
    assert all(len(c) == 1 for c in src.blocks.clusters)
    assert src.m_max == 4 and src.K == 1
    assert all(np.isfinite(res.history.losses))


def test_cluster_source_through_run_experiment(graph):
    row = run_experiment(graph, _cfg(graph), TrainPlan(lr=0.3, n_iters=3),
                         paradigm="cluster", b=48)
    assert row["paradigm"] == "cluster"
    assert row["fanouts"].startswith("clusters(k=")
    assert row["iters"] == 3


def test_cluster_source_requires_a_train_cluster(graph):
    with pytest.raises(ValueError, match="no cluster contains"):
        ClusterSource().bind(_no_train(graph), _cfg(graph),
                             TrainPlan(n_iters=1))


def test_cluster_source_rejects_bad_params():
    with pytest.raises(ValueError, match="clusters_per_batch"):
        ClusterSource(clusters_per_batch=0)
    with pytest.raises(ValueError, match="n_parts"):
        ClusterSource(n_parts=0)


# ---------------------------------------------------------------------------
# ImportanceSampledSource
# ---------------------------------------------------------------------------

def test_importance_weights_are_unbiased_by_construction(graph):
    src = ImportanceSampledSource().bind(graph, _cfg(graph),
                                         TrainPlan(n_iters=1))
    # E_p[w] = sum_j p_j * 1/(n p_j) = 1 regardless of the score scale
    assert np.isclose(float((src._p * src._w).sum()), 1.0)
    assert (src._w > 0).all()


def test_importance_deterministic_and_converges(graph):
    cfg = _cfg(graph)
    plan = TrainPlan(lr=0.3, n_iters=8, eval_every=4, seed=0)
    r1 = Trainer(graph, cfg, plan, source=ImportanceSampledSource()).run()
    r2 = Trainer(graph, cfg, plan, source=ImportanceSampledSource()).run()
    assert r1.history.losses == r2.history.losses
    assert r1.final_test_acc == r2.final_test_acc
    assert all(np.isfinite(r1.history.losses))


def test_importance_scores_need_not_sum_to_one(graph):
    """Scores are a PROPOSAL, not a distribution: scaling them by any
    constant (their sum is far from 1 either way) must not change the
    run — normalization and the 1/(n p) reweighting absorb it."""
    cfg = _cfg(graph)
    plan = TrainPlan(lr=0.3, n_iters=5, seed=0)
    s = (graph.degrees + 1).astype(np.float64)          # sums to ~2000
    r1 = Trainer(graph, cfg, plan,
                 source=ImportanceSampledSource(scores=s)).run()
    r2 = Trainer(graph, cfg, plan,
                 source=ImportanceSampledSource(scores=17.0 * s)).run()
    np.testing.assert_allclose(r1.history.losses, r2.history.losses,
                               rtol=1e-6, atol=1e-6)


def test_importance_batch_larger_than_train_split(graph):
    """Sampling WITH replacement makes b > n_train legal without
    padding: the batch just revisits nodes, weights keep the estimator
    unbiased."""
    n_train = len(graph.train_nodes)
    b = n_train + 16
    cfg = _cfg(graph, batch_size=b)
    src = ImportanceSampledSource(batch_size=b)
    res = Trainer(graph, cfg, TrainPlan(lr=0.3, n_iters=3, seed=0),
                  source=src).run()
    assert src.pad == 0
    assert res.history.nodes_processed[0] == b
    assert all(np.isfinite(res.history.losses))


def test_importance_grad_norm_scores_mode(graph):
    src = ImportanceSampledSource(scores="grad")
    res = Trainer(graph, _cfg(graph), TrainPlan(lr=0.3, n_iters=3, seed=0),
                  source=src).run()
    assert (src._p > 0).all()
    assert all(np.isfinite(res.history.losses))


def test_importance_rejects_bad_scores(graph):
    cfg, plan = _cfg(graph), TrainPlan(n_iters=1)
    with pytest.raises(ValueError, match="non-negative"):
        ImportanceSampledSource(
            scores=-np.ones(graph.n)).bind(graph, cfg, plan)
    with pytest.raises(ValueError, match="length"):
        ImportanceSampledSource(scores=np.ones(7)).bind(graph, cfg, plan)
    with pytest.raises(ValueError, match="unknown scores"):
        ImportanceSampledSource(scores="nope").bind(graph, cfg, plan)


def test_gnn_loss_weight_oracle():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(8, 3)).astype(np.float32)
    labels = rng.integers(0, 3, 8).astype(np.int32)
    w = rng.uniform(0.5, 2.0, 8).astype(np.float32)
    valid = np.ones(8, np.float32)
    got = float(gnn_loss(logits, labels, "ce", 3, valid=valid, weight=w))
    z = logits.astype(np.float64)
    rows = (np.log(np.exp(z).sum(-1))
            - z[np.arange(8), labels])
    assert np.isclose(got, float((rows * w).mean()), atol=1e-5)
    # weight of exactly 1.0 leaves the loss untouched
    plain = float(gnn_loss(logits, labels, "ce", 3))
    ones = float(gnn_loss(logits, labels, "ce", 3,
                          weight=np.ones(8, np.float32)))
    assert plain == ones


# ---------------------------------------------------------------------------
# ShardedSampledSource
# ---------------------------------------------------------------------------

def test_sharded_minibatch_bit_equals_plain_on_one_device(graph):
    """The mini-batch twin of PR 3's sharded full-graph equality: on a
    1-device mesh the host batches, compiled step and loss sequence are
    identical bit-for-bit."""
    cfg = _cfg(graph)
    plan = TrainPlan(lr=0.3, n_iters=6, eval_every=2, seed=0,
                     track_full_loss_every=3)
    r_plain = Trainer(graph, cfg, plan, source=SampledSource()).run()
    t = Trainer(graph, cfg, plan, source=ShardedSampledSource())
    r_shard = t.run()
    assert r_plain.history.losses == r_shard.history.losses
    assert r_plain.history.val_accs == r_shard.history.val_accs
    assert r_plain.history.full_losses == r_shard.history.full_losses
    assert r_plain.final_test_acc == r_shard.final_test_acc
    # stable input shardings from iteration 0: exactly one compile
    assert t._step._cache_size() == 1


def test_sharded_minibatch_batch_is_row_sharded(graph):
    from jax.sharding import NamedSharding
    cfg = _cfg(graph)
    src = ShardedSampledSource().bind(graph, cfg, TrainPlan(n_iters=2))
    stream = src.batches()
    batch, n = next(stream)
    import jax
    for leaf in jax.tree.leaves(batch):
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.spec[0] == "data"
    src.close()


_MULTIDEV_SCRIPT = r"""
import jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.data import make_sbm_graph
from repro.configs.base import GNNConfig
from repro.core.engine import (SampledSource, ShardedSampledSource,
                               Trainer, TrainPlan)
g = make_sbm_graph(n=240, n_classes=4, avg_degree=8, feat_dim=16, seed=5)
cfg = GNNConfig(name="md", model="graphsage", n_nodes=g.n, feat_dim=16,
                hidden=32, n_classes=g.n_classes, n_layers=2,
                fanout=(5, 3), batch_size=30, loss="ce")
plan = TrainPlan(lr=0.3, n_iters=4, eval_every=2, seed=0)
r1 = Trainer(g, cfg, plan, source=SampledSource(batch_size=30)).run()
src = ShardedSampledSource(batch_size=30)   # 30 % 4 != 0 -> pads to 32
r2 = Trainer(g, cfg, plan, source=src).run()
assert src.b == 32 and src.pad == 2, (src.b, src.pad)
np.testing.assert_allclose(r1.history.losses, r2.history.losses,
                           atol=1e-5, rtol=1e-5)
print("MULTIDEV_MB_OK", r2.history.losses)
"""


def test_sharded_minibatch_runs_on_multidevice_cpu_mesh():
    """4 virtual CPU devices (own process — the flag must be set before
    jax initializes): data-parallel mini-batches with a non-divisible b
    (masked-row padding) match the single-device losses to float
    tolerance."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEV_MB_OK" in out.stdout


# ---------------------------------------------------------------------------
# Boundary paths shared by the sources
# ---------------------------------------------------------------------------

def test_batch_size_equals_train_split_exact_fit(graph):
    n_train = len(graph.train_nodes)
    cfg = _cfg(graph, batch_size=n_train)
    src = SampledSource(batch_size=n_train)
    res = Trainer(graph, cfg, TrainPlan(lr=0.3, n_iters=3, seed=0),
                  source=src).run()
    assert src.pad == 0                        # no masked rows needed
    assert res.history.nodes_processed[0] == n_train


def test_fanout_beyond_max_degree_keeps_all_neighbors(graph):
    beta = graph.d_max + 3
    rng = np.random.default_rng(0)
    targets = graph.train_nodes[:32]
    fb = expand_batch(rng, graph, targets, (beta,))
    # every row keeps exactly its true degree: no truncation, rest padded
    np.testing.assert_array_equal(fb.masks[0].sum(-1),
                                  graph.degrees[targets])
    cfg = _cfg(graph, n_layers=1, fanout=(beta,), batch_size=32)
    res = Trainer(graph, cfg, TrainPlan(lr=0.3, n_iters=2, seed=0),
                  source=SampledSource(batch_size=32, fanouts=(beta,))
                  ).run()
    assert all(np.isfinite(res.history.losses))


def test_sweep_runs_the_sampler_cube(graph):
    cfg = _cfg(graph, n_layers=1, fanout=(3,), batch_size=32)
    rows = sweep(graph, cfg, TrainPlan(lr=0.3, n_iters=2),
                 batch_sizes=[32], fanout_grid=[(3,)],
                 sources=("minibatch", "cluster", "importance"))
    assert [r["paradigm"] for r in rows] == ["minibatch", "cluster",
                                             "importance"]


def test_sweep_does_not_duplicate_cluster_points_across_fanouts(graph):
    """Fan-out does not apply to cluster batches: a fanout grid must not
    rerun identical, identically-labelled cluster points."""
    cfg = _cfg(graph, n_layers=1, fanout=(3,), batch_size=32)
    rows = sweep(graph, cfg, TrainPlan(lr=0.3, n_iters=2),
                 batch_sizes=[32], fanout_grid=[(2,), (3,)],
                 sources=("minibatch", "cluster"))
    assert [r["paradigm"] for r in rows].count("cluster") == 1
    assert [r["paradigm"] for r in rows].count("minibatch") == 2


def test_make_source_dispatches_all_paradigms():
    assert isinstance(make_source("minibatch_sharded", b=8, fanouts=(2,)),
                      ShardedSampledSource)
    assert isinstance(make_source("cluster", b=8), ClusterSource)
    assert isinstance(make_source("importance", b=8, fanouts=(2,)),
                      ImportanceSampledSource)
    with pytest.raises(ValueError, match="paradigm"):
        make_source("nope")


# ---------------------------------------------------------------------------
# Satellite: max_deg truthiness (explicit 0 must error, not fall back)
# ---------------------------------------------------------------------------

def test_max_deg_zero_is_rejected_not_silently_uncapped(graph):
    with pytest.raises(ValueError, match="max_deg"):
        to_ell(graph, max_deg=0)
    with pytest.raises(ValueError, match="max_deg"):
        _device_ell(graph, 0)
    with pytest.raises(ValueError, match="max_deg"):
        FullGraphSource(max_deg=0).bind(graph, _cfg(graph),
                                        TrainPlan(n_iters=1))
    with pytest.raises(ValueError, match="max_deg"):
        ShardedFullGraphSource(max_deg=-2).bind(graph, _cfg(graph),
                                                TrainPlan(n_iters=1))
    # None still means "uncapped d_max"
    idx, w, ws = to_ell(graph, max_deg=None)
    assert idx.shape[1] == graph.d_max


# ---------------------------------------------------------------------------
# Satellite: empty/overflowed train split fails with a clear message
# ---------------------------------------------------------------------------

def test_sample_batch_empty_train_split_clear_error(graph):
    g0 = _no_train(graph)
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="n_train=0"):
        sample_batch(rng, g0, 16, (3, 2))


def test_sample_batch_strict_names_b_and_n_train(graph):
    rng = np.random.default_rng(0)
    n_train = len(graph.train_nodes)
    with pytest.raises(ValueError,
                       match=rf"b={n_train + 5} > n_train={n_train}"):
        sample_batch(rng, graph, n_train + 5, (3, 2), strict=True)
    with pytest.raises(ValueError, match="batch_size"):
        sample_batch(rng, graph, 0, (3, 2))
    # non-strict keeps the engine's clamp-then-pad contract
    fb = sample_batch(rng, graph, n_train + 5, (3, 2))
    assert fb.batch_size == n_train


def test_sampled_source_checks_train_split_up_front(graph):
    g0 = _no_train(graph)
    with pytest.raises(ValueError, match="no training nodes"):
        SampledSource().bind(g0, _cfg(g0), TrainPlan(n_iters=1))


def test_gnnconfig_rejects_batch_beyond_graph(graph):
    cfg = _cfg(graph, batch_size=graph.n + 1)
    with pytest.raises(ValueError, match="n_nodes"):
        cfg.validate()
    _cfg(graph, batch_size=graph.n).validate()   # boundary is legal


# ---------------------------------------------------------------------------
# Satellite: Prefetcher close() diagnoses a stuck worker; a worker dying
# mid-batch releases its staging slot
# ---------------------------------------------------------------------------

def test_prefetcher_close_warns_on_stuck_worker(graph):
    release = threading.Event()

    def stuck_payload(g, fb):
        release.wait(timeout=30)
        return []

    pf = Prefetcher(graph, 8, (2,), payload_fn=stuck_payload)
    time.sleep(0.2)                    # let the worker enter the payload
    try:
        with pytest.warns(RuntimeWarning, match="did not exit"):
            pf.close(timeout=0.3)
    finally:
        release.set()                  # let the daemon thread finish
    pf._thread.join(timeout=5)


def test_prefetcher_surfaces_worker_errors(graph):
    def boom(rng, g, b, fanouts):
        raise RuntimeError("sampler exploded")

    pf = Prefetcher(graph, 8, (2,), sample_fn=boom)
    with pytest.raises(RuntimeError, match="sampler exploded"):
        pf.next()
    pf.close()


def test_host_batch_error_releases_staging_slot(graph):
    cfg = _cfg(graph)
    src = SampledSource(prefetch=False).bind(graph, cfg,
                                             TrainPlan(n_iters=2))
    free0 = src._ring._free.qsize()
    rng = np.random.default_rng(0)
    fb = sample_batch(rng, graph, src.b, src.fanouts)
    fb.nodes[1][:] = graph.n + 99      # out-of-range gather -> IndexError
    with pytest.raises(IndexError):
        src._host_batch(graph, fb)
    assert src._ring._free.qsize() == free0    # slot was NOT leaked
    src.close()


# ---------------------------------------------------------------------------
# Satellite: bench gate tolerates variants the baseline predates
# ---------------------------------------------------------------------------

def test_bench_gate_skips_variants_missing_from_baseline(tmp_path):
    sys.path.insert(0, REPO)
    try:
        from benchmarks import bench_engine
    finally:
        sys.path.pop(0)
    base = {"smoke": True, "rows": [
        {"variant": "minibatch+fast", "kernel": 0,
         "steady_steps_per_s": 100.0}]}
    path = tmp_path / "BENCH_engine.json"
    path.write_text(json.dumps(base))
    rows = [
        {"variant": "minibatch+fast", "kernel": 0,
         "steady_steps_per_s": 99.0, "time_to_first_step_s": 0.1},
        # sources this PR introduced: absent from the baseline -> the
        # gate reports them but must NOT fail
        {"variant": "cluster+fast", "kernel": 0,
         "steady_steps_per_s": 1.0, "time_to_first_step_s": 0.1},
        {"variant": "importance+fast", "kernel": 0,
         "steady_steps_per_s": 1.0, "time_to_first_step_s": 0.1},
    ]
    failures = bench_engine.check_regression(rows, str(path), smoke=True)
    assert failures == []
