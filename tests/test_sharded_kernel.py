"""Mesh-partitioned Pallas aggregation (PR 5): the shard_map'd kernel
entry points and their engine wiring.

Equivalence contract (extends the PR 3/PR 4 pattern):
- on a 1-DEVICE mesh the sharded kernel path is BIT-identical to the
  unsharded kernel path — forward and gradients (the shard-local VJP
  mirrors the unsharded one; the dfeats psum is an identity there);
- on a 4-DEVICE CPU mesh (interpret mode, own subprocess — the XLA
  device-count flag must be set before jax initializes) it matches the
  einsum path to float tolerance, fwd + grads, for BOTH sharded
  sources, compiling the sharded x kernel step exactly once.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sharding as sh
from repro.configs.base import GNNConfig
from repro.core.engine import (FullGraphSource, SampledSource,
                               ShardedFullGraphSource,
                               ShardedSampledSource, Trainer, TrainPlan)
from repro.data import make_sbm_graph
from repro.kernels.neighbor_agg.ops import (neighbor_agg,
                                            neighbor_agg_batch_sharded,
                                            neighbor_agg_sharded)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KW = dict(interpret=True, d_tile=8, b_tile=4, k_slab=2)


def _cfg(g, **kw):
    base = dict(name="sk", model="gcn", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=16,
                n_classes=g.n_classes, n_layers=2, fanout=(4, 3),
                batch_size=32, loss="ce", use_agg_kernel=True,
                agg_interpret=True, agg_b_tile=4, agg_d_tile=8,
                agg_k_slab=2)
    base.update(kw)
    return GNNConfig(**base)


@pytest.fixture(scope="module")
def graph():
    return make_sbm_graph(n=120, n_classes=4, avg_degree=8, feat_dim=16,
                          seed=7)


# ---------------------------------------------------------------------------
# Op level: 1-device mesh == unsharded kernel, bit for bit
# ---------------------------------------------------------------------------

def _operands(fused, b=26, n=37, d=19, k=5, seed=0):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, size=(b, k)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    if not fused:
        return feats, idx, w
    sr = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    ws = jnp.asarray(rng.normal(size=(b,)).astype(np.float32))
    return feats, idx, w, sr, ws


@pytest.mark.parametrize("fused", [False, True])
def test_sharded_op_bit_equal_on_one_device_mesh(fused):
    args = _operands(fused)
    mesh = sh.node_mesh(1)
    base = neighbor_agg(*args, use_kernel=True, kernel="tiled", **KW)
    shrd = neighbor_agg_sharded(*args, mesh=mesh, **KW)
    assert np.array_equal(np.asarray(base), np.asarray(shrd))
    # grads bit-equal too: feats, w (+ self_rows, w_self)
    diff = (0, 2) + ((3, 4) if fused else ())

    def loss(fn):
        return lambda *a: (fn(*a) ** 2).sum()

    gb = jax.grad(loss(lambda *a: neighbor_agg(
        *a, use_kernel=True, kernel="tiled", **KW)), argnums=diff)(*args)
    gs = jax.grad(loss(lambda *a: neighbor_agg_sharded(
        *a, mesh=mesh, **KW)), argnums=diff)(*args)
    for a, b in zip(gb, gs):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fused", [False, True])
def test_batch_sharded_op_bit_equal_on_one_device_mesh(fused):
    rng = np.random.default_rng(3)
    b, k, d = 8, 5, 19
    h_nb = jnp.asarray(rng.normal(size=(b, k, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(b, k)).astype(np.float32))
    args = (w, h_nb)
    if fused:
        args += (jnp.asarray(rng.normal(size=(b, d)).astype(np.float32)),
                 jnp.asarray(rng.normal(size=(b,)).astype(np.float32)))
    mesh = sh.node_mesh(1)

    def unsharded(ww, nb, *rest):
        table = nb.reshape(-1, d)
        ids = jnp.arange(b * k, dtype=jnp.int32).reshape(b, k)
        return neighbor_agg(table, ids, ww, *rest, use_kernel=True,
                            kernel="tiled", **KW)

    base = unsharded(*args)
    shrd = neighbor_agg_batch_sharded(*args, mesh=mesh, **KW)
    assert np.array_equal(np.asarray(base), np.asarray(shrd))
    diff = tuple(range(len(args)))
    gb = jax.grad(lambda *a: (unsharded(*a) ** 2).sum(),
                  argnums=diff)(*args)
    gs = jax.grad(lambda *a: (neighbor_agg_batch_sharded(
        *a, mesh=mesh, **KW) ** 2).sum(), argnums=diff)(*args)
    for a, b_ in zip(gb, gs):
        assert np.array_equal(np.asarray(a), np.asarray(b_))


def test_sharded_op_pads_rows_to_mesh_multiple():
    """Internal row padding: any B is legal for the ELL entry (eval
    feeds n-row ELLs that need not divide the mesh)."""
    args = _operands(False, b=7)
    mesh = sh.node_mesh(1)
    out = neighbor_agg_sharded(*args, mesh=mesh, **KW)
    assert out.shape[0] == 7


# ---------------------------------------------------------------------------
# Engine level: sharded sources x kernel, 1-device mesh bit-equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "graphsage"])
def test_sharded_fullgraph_kernel_bit_equal_one_device(graph, model):
    """No guard error anymore, and the sharded x kernel loss sequence is
    bit-identical to the plain kernel path on a 1-device mesh."""
    cfg = _cfg(graph, model=model)
    plan = TrainPlan(lr=0.3, n_iters=4, eval_every=2, seed=0)
    r1 = Trainer(graph, cfg, plan, source=FullGraphSource()).run()
    t = Trainer(graph, cfg, plan, source=ShardedFullGraphSource())
    r2 = t.run()
    assert r1.history.losses == r2.history.losses
    assert r1.history.val_accs == r2.history.val_accs
    assert r1.final_test_acc == r2.final_test_acc
    assert t._step._cache_size() == 1


@pytest.mark.parametrize("model", ["gcn", "graphsage"])
def test_sharded_minibatch_kernel_bit_equal_one_device(graph, model):
    cfg = _cfg(graph, model=model)
    plan = TrainPlan(lr=0.3, n_iters=4, eval_every=2, seed=0,
                     track_full_loss_every=2)
    r1 = Trainer(graph, cfg, plan,
                 source=SampledSource(batch_size=32)).run()
    t = Trainer(graph, cfg, plan,
                source=ShardedSampledSource(batch_size=32))
    r2 = t.run()
    assert r1.history.losses == r2.history.losses
    assert r1.history.val_accs == r2.history.val_accs
    assert r1.history.full_losses == r2.history.full_losses
    assert r1.final_test_acc == r2.final_test_acc
    assert t._step._cache_size() == 1


def test_sharded_kernel_step_cached_across_trainers(graph):
    """The sharded x kernel step must reuse ONE compiled step across
    Trainer instances (memoized node_mesh keeps the consts' identity —
    and with it the per-graph step-cache key — stable)."""
    cfg = _cfg(graph)
    plan = TrainPlan(lr=0.3, n_iters=2, seed=0)
    t1 = Trainer(graph, cfg, plan, source=ShardedFullGraphSource())
    t1.run()
    t2 = Trainer(graph, cfg, plan, source=ShardedFullGraphSource())
    assert t2._step is t1._step
    t2.run()
    assert t2._step._cache_size() == 1


# ---------------------------------------------------------------------------
# 4-device CPU mesh (subprocess): kernel path == einsum path, fwd+grads
# ---------------------------------------------------------------------------

_MULTIDEV_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro import sharding as sh
from repro.data import make_sbm_graph
from repro.configs.base import GNNConfig
from repro.core.engine import (ShardedFullGraphSource,
                               ShardedSampledSource, Trainer, TrainPlan)
from repro.kernels.neighbor_agg.ops import (neighbor_agg_batch_sharded,
                                            neighbor_agg_sharded)

mesh = sh.node_mesh()
KW = dict(interpret=True, d_tile=8, b_tile=4, k_slab=2)

# -- op level: fwd + VJP (incl. the psum'd dfeats) vs the einsum ref --------
rng = np.random.default_rng(0)
N, D, B, K = 37, 19, 26, 5       # B deliberately NOT divisible by 4
feats = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
idx = jnp.asarray(rng.integers(0, N, size=(B, K)).astype(np.int32))
w = jnp.asarray(rng.normal(size=(B, K)).astype(np.float32))

def ref(f, ww):
    return jnp.einsum("bk,bkd->bd", ww, jnp.take(f, idx, axis=0))

out = neighbor_agg_sharded(feats, idx, w, mesh=mesh, **KW)
np.testing.assert_allclose(out, ref(feats, w), rtol=1e-5, atol=1e-5)
gs = jax.grad(lambda f, ww: (neighbor_agg_sharded(
    f, idx, ww, mesh=mesh, **KW) ** 2).sum(), argnums=(0, 1))(feats, w)
gr = jax.grad(lambda f, ww: (ref(f, ww) ** 2).sum(),
              argnums=(0, 1))(feats, w)
for a, b in zip(gs, gr):
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

# indivisible rows are rejected on the batch-sharded (fan-out) entry
try:
    neighbor_agg_batch_sharded(w[:6], jnp.zeros((6, K, D)), mesh=mesh, **KW)
    raise SystemExit("expected ValueError for B=6 on 4 shards")
except ValueError:
    pass

# -- engine level: sharded sources, kernel vs einsum on the SAME mesh -------
g = make_sbm_graph(n=202, n_classes=4, avg_degree=8, feat_dim=16, seed=5)
base = GNNConfig(name="md", model="gcn", n_nodes=g.n, feat_dim=16,
                 hidden=16, n_classes=g.n_classes, n_layers=2,
                 fanout=(4, 3), batch_size=30, loss="ce")
kcfg = dataclasses.replace(base, use_agg_kernel=True, agg_interpret=True,
                           agg_b_tile=4, agg_d_tile=8, agg_k_slab=2)
plan = TrainPlan(lr=0.3, n_iters=4, eval_every=2, seed=0)
for make in (lambda: ShardedFullGraphSource(),
             lambda: ShardedSampledSource(batch_size=30)):
    r_e = Trainer(g, base, plan, source=make()).run()
    t_k = Trainer(g, kcfg, plan, source=make())
    r_k = t_k.run()
    np.testing.assert_allclose(r_e.history.losses, r_k.history.losses,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(r_e.history.val_accs, r_k.history.val_accs,
                               rtol=1e-5, atol=1e-5)
    # compile-once for the sharded x kernel step
    assert t_k._step._cache_size() == 1, t_k._step._cache_size()
print("MULTIDEV_KERNEL_OK")
"""


def test_sharded_kernel_on_multidevice_cpu_mesh():
    """4 virtual CPU devices (own process: the flag must be set before
    jax initializes): the shard_map'd kernel matches the einsum path —
    op-level fwd/VJP and both sharded sources' training runs — and the
    sharded x kernel step compiles exactly once."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEV_KERNEL_OK" in out.stdout
