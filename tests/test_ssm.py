"""SSD correctness: the chunked algorithm vs a naive per-step recurrence,
and decode-state equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked


def _naive_ssd(x, dt, a_neg, bmat, cmat):
    """h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t ;  y_t = C_t h_t."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    x = np.asarray(x, np.float64)
    dt = np.asarray(dt, np.float64)
    a = np.asarray(a_neg, np.float64)
    bm = np.asarray(bmat, np.float64)
    cm = np.asarray(cmat, np.float64)
    for t in range(s):
        decay = np.exp(dt[:, t] * a[None, :])                 # [b,h]
        upd = np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], bm[:, t])
        state = decay[:, :, None, None] * state + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", cm[:, t], state)
    return ys, state


@pytest.mark.parametrize("s,chunk_note", [(32, "multi-chunk via CHUNK=256->1"),
                                          (256, "one chunk"),
                                          (512, "two chunks")])
def test_ssd_chunked_matches_naive(s, chunk_note, rng):
    b, h, p, n = 2, 3, 4, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a_neg = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, final = ssd_chunked(x, dt, a_neg, bm, cm)
    y_ref, final_ref = _naive_ssd(x, dt, a_neg, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final), final_ref, atol=1e-4,
                               rtol=1e-4)


def test_ssd_initial_state_continuation(rng):
    """ssd(x[:half]) state feeds ssd(x[half:]) == ssd(x) — the
    prefill->decode contract."""
    b, s, h, p, n = 1, 512, 2, 4, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    a_neg = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y_all, fin_all = ssd_chunked(x, dt, a_neg, bm, cm)
    half = 256
    y1, st = ssd_chunked(x[:, :half], dt[:, :half], a_neg, bm[:, :half],
                         cm[:, :half])
    y2, fin = ssd_chunked(x[:, half:], dt[:, half:], a_neg, bm[:, half:],
                          cm[:, half:], init_state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_all),
                               atol=1e-4, rtol=1e-4)
