"""The paper's iteration bounds (Thm 1, 2, B.4, D.2) and Remark 3.1/3.2
predictions, as testable monotonicities."""
import numpy as np
import pytest

from repro.core import theory as T


def test_thm1_mse_batch_monotone_increasing():
    """Remark 3.1: under MSE, more batch -> MORE iterations."""
    ts = [T.t_mse_minibatch(1000, 8, b, 10) for b in (32, 64, 128, 256)]
    assert all(a < b for a, b in zip(ts, ts[1:]))


def test_thm1_mse_fanout_monotone_decreasing():
    ts = [T.t_mse_minibatch(1000, 8, 64, bt) for bt in (2, 5, 10, 20)]
    assert all(a > b for a, b in zip(ts, ts[1:]))


def test_thm2_ce_batch_monotone_decreasing():
    """Remark 3.1: under CE, more batch -> FEWER iterations."""
    ts = [T.t_ce_minibatch(1000, b, 10) for b in (32, 64, 128, 256)]
    assert all(a > b for a, b in zip(ts, ts[1:]))


def test_thm2_ce_fanout_monotone_decreasing():
    ts = [T.t_ce_minibatch(1000, 64, bt) for bt in (2, 5, 10, 20)]
    assert all(a > b for a, b in zip(ts, ts[1:]))


def test_fullgraph_is_limit_of_minibatch():
    """At b = n_train, beta = d_max the mini-batch bounds reduce to the
    full-graph bounds (paper: 'the upper bound ... matches')."""
    n, h, dmax, eps = 500, 4, 20, 0.1
    mse_mini = T.t_mse_minibatch(n, h, n, dmax, eps)
    mse_full = T.t_mse_fullgraph(n, h, dmax, eps) * n ** -1  # b^{5/2}=n^{5/2}
    # T_mini(b=n) = n * h^2 * n^{5/2} ... = n^{7/2} h^2 / sqrt(dmax) = T_full
    assert np.isclose(mse_mini, T.t_mse_fullgraph(n, h, dmax, eps),
                      rtol=1e-9)
    ce_mini = T.t_ce_minibatch(n, n, dmax, eps=eps)
    ce_full = T.t_ce_fullgraph(n, dmax, eps=eps)
    assert np.isclose(ce_mini, ce_full, rtol=1e-9)


def test_remark32_slopes():
    """|dT/dbeta| magnitudes: MSE slope grows with b, CE slope shrinks
    with b; both shrink with beta (the 'moderate beta' advice)."""
    assert T.slope_mse(128, 10) > T.slope_mse(32, 10)
    assert T.slope_ce(128, 10) < T.slope_ce(32, 10)
    assert T.slope_mse(64, 20) < T.slope_mse(64, 5)
    assert T.slope_ce(64, 20) < T.slope_ce(64, 5)


def test_testbed_losses(rng):
    import jax
    import jax.numpy as jnp
    from repro.core.theory import (init_testbed, make_v, testbed_ce_loss,
                                   testbed_mse_loss)
    w = init_testbed(jax.random.key(0), 16, 8)
    agg = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    onehot = jax.nn.one_hot(jnp.asarray(rng.integers(0, 8, 32)), 8)
    l1 = testbed_mse_loss(w, agg, onehot)
    assert np.isfinite(float(l1)) and float(l1) > 0
    y_pm = jnp.asarray(rng.choice([-1.0, 1.0], 32), jnp.float32)
    l2 = testbed_ce_loss(w, agg, y_pm, make_v(8))
    assert np.isfinite(float(l2)) and float(l2) > 0
