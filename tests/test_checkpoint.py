"""Crash-safe checkpoint layer: manifest + checksums, atomic write
ordering (kill at any failpoint leaves the directory restorable at the
previous step), retention, stale-tmp GC, and the typed restore errors."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, CheckpointDtypeError,
                              CheckpointKeyError, CheckpointShapeError,
                              available_steps, latest_step, load_metadata,
                              restore_checkpoint, save_checkpoint)
from repro.checkpoint.ckpt import MANIFEST
from repro.core import faults


def _tree(seed=0, shape=(4, 3)):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=shape).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=shape[1:]).astype(np.float32))}


@pytest.fixture(autouse=True)
def _no_armed_failpoints():
    yield
    faults.disarm()


# ---------------------------------------------------------------------------
# Manifest + checksums
# ---------------------------------------------------------------------------

def test_manifest_records_completed_steps(tmp_path):
    d = str(tmp_path)
    for step in (1, 2, 5):
        save_checkpoint(d, step, _tree(step), {"step": step})
    assert available_steps(d) == [1, 2, 5]
    assert latest_step(d) == 5
    m = json.load(open(os.path.join(d, MANIFEST)))
    assert sorted(m["steps"]) == ["1", "2", "5"]
    for entry in m["steps"].values():
        assert len(entry["sha256"]) == 64 and entry["has_meta"]
    assert load_metadata(d) == {"step": 5}
    assert load_metadata(d, 1) == {"step": 1}


def test_restore_verifies_checksum(tmp_path):
    d = str(tmp_path)
    t = _tree()
    save_checkpoint(d, 1, t)
    path = os.path.join(d, "ckpt_00000001.npz")
    with open(path, "r+b") as f:        # flip one byte -> corrupt
        f.seek(20)
        b = f.read(1)
        f.seek(20)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(CheckpointCorruptError, match="checksum mismatch"):
        restore_checkpoint(d, t)


def test_latest_step_ignores_orphan_npz(tmp_path):
    """An npz not recorded by the manifest (crash between rename and
    manifest write) is invisible to readers."""
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    np.savez(os.path.join(d, "ckpt_00000009.npz"), junk=np.zeros(3))
    assert latest_step(d) == 1


def test_adopts_pre_manifest_directory(tmp_path):
    """Old-format directories (no MANIFEST.json) keep working and are
    adopted into the manifest by the next save."""
    d = str(tmp_path)
    t = _tree()
    save_checkpoint(d, 1, t)
    os.unlink(os.path.join(d, MANIFEST))
    assert latest_step(d) == 1                 # scan fallback
    out = restore_checkpoint(d, t)             # no recorded sha: no verify
    np.testing.assert_array_equal(out["w"], np.asarray(t["w"]))
    save_checkpoint(d, 2, _tree(2))
    assert available_steps(d) == [1, 2]        # step 1 adopted, not hidden


# ---------------------------------------------------------------------------
# Typed restore errors
# ---------------------------------------------------------------------------

def test_restore_key_mismatch_names_leaves(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    with pytest.raises(CheckpointKeyError) as ei:
        restore_checkpoint(d, {"w": _tree()["w"], "extra": jnp.zeros(2)})
    assert "extra" in str(ei.value) and "b" in str(ei.value)


def test_restore_shape_mismatch_names_leaf(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    bad = _tree()
    bad["w"] = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(CheckpointShapeError, match="'w'"):
        restore_checkpoint(d, bad)


def test_restore_dtype_mismatch_names_leaf(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    bad = _tree()
    # numpy like-leaf: jnp would silently downcast to f32 without x64
    bad["b"] = np.zeros(bad["b"].shape, np.float64)
    with pytest.raises(CheckpointDtypeError, match="'b'"):
        restore_checkpoint(d, bad)


# ---------------------------------------------------------------------------
# Retention + tmp GC
# ---------------------------------------------------------------------------

def test_keep_last_retention(tmp_path):
    d = str(tmp_path)
    for step in range(1, 6):
        save_checkpoint(d, step, _tree(step), {"s": step}, keep_last=2)
    assert available_steps(d) == [4, 5]
    files = sorted(os.listdir(d))
    assert "ckpt_00000004.npz" in files and "ckpt_00000005.npz" in files
    assert not any(f.startswith(("ckpt_00000001", "meta_00000001",
                                 "ckpt_00000002", "ckpt_00000003"))
                   for f in files)
    # retained steps still restore + verify
    out = restore_checkpoint(d, _tree(), step=4)
    np.testing.assert_array_equal(out["w"], np.asarray(_tree(4)["w"]))


def test_stale_tmp_gc(tmp_path):
    d = str(tmp_path)
    os.makedirs(d, exist_ok=True)
    stale = os.path.join(d, "deadbeef.tmp")
    open(stale, "w").write("leftover")
    save_checkpoint(d, 1, _tree())
    assert not os.path.exists(stale)


# ---------------------------------------------------------------------------
# Crash failpoints: kill at every stage, directory stays consistent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site", ["ckpt.before_npz_rename",
                                  "ckpt.after_npz_rename",
                                  "ckpt.after_meta"])
def test_kill_mid_save_restorable_at_previous_step(tmp_path, site):
    d = str(tmp_path)
    t1, t2 = _tree(1), _tree(2)
    save_checkpoint(d, 1, t1, {"s": 1})
    with faults.armed(site):
        with pytest.raises(faults.SimulatedCrash):
            save_checkpoint(d, 2, t2, {"s": 2})
    # the interrupted step never became visible ...
    assert latest_step(d) == 1
    out = restore_checkpoint(d, t1)
    np.testing.assert_array_equal(out["w"], np.asarray(t1["w"]))
    assert load_metadata(d) == {"s": 1}
    # ... and a retried save completes normally (GCing any stale tmp)
    save_checkpoint(d, 2, t2, {"s": 2})
    assert latest_step(d) == 2
    assert not any(f.endswith(".tmp") for f in os.listdir(d))


def test_kill_before_rename_leaves_tmp_for_gc(tmp_path):
    """SimulatedCrash is a BaseException: the save's `except Exception`
    cleanup must NOT swallow it (that would be unlike real process
    death) — the tmp file survives until the next save GCs it."""
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    with faults.armed("ckpt.before_npz_rename"):
        with pytest.raises(faults.SimulatedCrash):
            save_checkpoint(d, 2, _tree(2))
    assert any(f.endswith(".tmp") for f in os.listdir(d))
    save_checkpoint(d, 2, _tree(2))
    assert not any(f.endswith(".tmp") for f in os.listdir(d))


def test_corrupt_manifest_is_loud(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    open(os.path.join(d, MANIFEST), "w").write("{not json")
    with pytest.raises(CheckpointCorruptError, match="manifest"):
        latest_step(d)
