"""Device-resident fast path: donation/deferred-sync invariance, one
compile per grid point (incl. padded partial batches), per-graph
step/ELL cache behavior, idempotent close, the NODES-sharded full-graph
source (1-device bit-equality + a 4-device subprocess run), and the
engine bench's regression gate."""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import GNNConfig
from repro.core.engine import (Callback, FullGraphSource, SampledSource,
                               ShardedFullGraphSource, Trainer, TrainPlan,
                               _device_ell)
from repro.data import make_sbm_graph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(g, **kw):
    base = dict(name="tp", model="graphsage", n_nodes=g.n,
                feat_dim=g.feats.shape[1], hidden=32,
                n_classes=g.n_classes, n_layers=2, fanout=(5, 3),
                batch_size=64, loss="ce")
    base.update(kw)
    return GNNConfig(**base)


def _fresh_graph(n=240, seed=11, **kw):
    return make_sbm_graph(n=n, n_classes=4, avg_degree=8, feat_dim=16,
                          seed=seed, **kw)


# ---------------------------------------------------------------------------
# Donation + deferred sync: pure transport optimizations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source_fn", [FullGraphSource,
                                       lambda: SampledSource()])
def test_fast_path_off_is_identical(source_fn):
    """donate + deferred_sync must not change losses, val accs, tracked
    full losses, or the final test accuracy (bit-for-bit)."""
    g = _fresh_graph(seed=12)
    cfg = _cfg(g)
    on = TrainPlan(lr=0.3, n_iters=8, eval_every=3, seed=0,
                   track_full_loss_every=4)
    off = dataclasses.replace(on, donate=False, deferred_sync=False)
    r_on = Trainer(g, cfg, on, source=source_fn()).run()
    r_off = Trainer(g, cfg, off, source=source_fn()).run()
    assert r_on.history.losses == r_off.history.losses
    assert r_on.history.val_accs == r_off.history.val_accs
    assert r_on.history.full_losses == r_off.history.full_losses
    assert r_on.final_test_acc == r_off.final_test_acc


def test_deferred_sync_drains_pending_on_callback_stop():
    """A callback stop mid-pipeline drains the lagged record: History
    stays aligned with the params the run returns."""
    g = _fresh_graph(seed=13)

    class StopAt3(Callback):
        def on_step(self, state):
            if state.it == 3:
                state.request_stop("by-callback")

    plan = TrainPlan(lr=0.3, n_iters=20, eval_every=100, seed=0)
    res = Trainer(g, _cfg(g), plan, source=FullGraphSource(),
                  extra_callbacks=[StopAt3()]).run()
    assert res.stop_reason == "by-callback"
    # record 3 triggered the stop while step 4 was already dispatched;
    # the drain records it, so params == params after the last row
    assert len(res.history.losses) == 5


def test_stop_targets_fall_back_to_synchronous():
    """target_loss runs need the loss on host immediately — History must
    end exactly at the crossing iteration (legacy semantics)."""
    g = _fresh_graph(seed=14)
    plan = TrainPlan(lr=0.3, n_iters=100, target_loss=1.0, seed=0)
    res = Trainer(g, _cfg(g), plan, source=FullGraphSource()).run()
    assert res.history.losses[-1] <= 1.0
    assert all(l > 1.0 for l in res.history.losses[:-1])


# ---------------------------------------------------------------------------
# Compiled-step caching + partial-batch padding
# ---------------------------------------------------------------------------

def test_step_cached_across_trainers_and_compiles_once():
    g = _fresh_graph(seed=15)
    cfg = _cfg(g)
    plan = TrainPlan(lr=0.3, n_iters=4, seed=0)
    t1 = Trainer(g, cfg, plan, source=FullGraphSource())
    t1.run()
    assert t1._step._cache_size() == 1
    t2 = Trainer(g, cfg, dataclasses.replace(plan, seed=1),
                 source=FullGraphSource())
    assert t2._step is t1._step          # same compiled step object
    t2.run()
    assert t2._step._cache_size() == 1   # no re-trace across Trainers


def test_partial_batch_pads_to_plan_batch_size():
    """b > n_train: every batch pads up to b with masked-out rows, the
    grid point compiles exactly ONE step, the loss sequence matches the
    exact-fit batch size to float-sum tolerance, and nodes_processed
    records the VALID count."""
    g = _fresh_graph(n=60, seed=16)
    n_train = len(g.train_nodes)
    b = n_train + 18
    cfg = _cfg(g, n_layers=2, fanout=(4, 2), batch_size=b)
    plan = TrainPlan(lr=0.3, n_iters=6, eval_every=3, seed=0)
    tp = Trainer(g, cfg, plan, source=SampledSource(batch_size=b))
    rp = tp.run()
    assert tp._step._cache_size() == 1
    assert rp.history.nodes_processed[0] == n_train
    re = Trainer(g, cfg, plan, source=SampledSource(batch_size=n_train)
                 ).run()
    np.testing.assert_allclose(rp.history.losses, re.history.losses,
                               atol=1e-6, rtol=1e-6)


def test_sampled_ring_grows_one_slot_under_deferred_sync():
    g = _fresh_graph(seed=17)
    cfg = _cfg(g)
    deferred = SampledSource().bind(g, cfg, TrainPlan(n_iters=2))
    synced = SampledSource().bind(
        g, cfg, TrainPlan(n_iters=2, deferred_sync=False))
    assert deferred._ring._free.qsize() == synced._ring._free.qsize() + 1


# ---------------------------------------------------------------------------
# Per-graph cache eviction + idempotent close
# ---------------------------------------------------------------------------

def test_device_ell_evicts_stale_keys():
    """One resident ELL besides "base": a sweep over distinct max_deg
    values must not accrete one [n, K] upload per grid point."""
    g = _fresh_graph(seed=18)
    _device_ell(g, 4)
    assert 4 in g._ell_cache
    _device_ell(g, 6)
    assert 6 in g._ell_cache and 4 not in g._ell_cache
    assert "base" in g._ell_cache
    _device_ell(g)                       # full width evicts the capped
    assert g.d_max in g._ell_cache and 6 not in g._ell_cache


def test_source_close_is_idempotent():
    g = _fresh_graph(seed=19)
    cfg = _cfg(g)
    plan = TrainPlan(lr=0.3, n_iters=3, seed=0)
    for src in (FullGraphSource(), SampledSource()):
        t = Trainer(g, cfg, plan, source=src)
        t.run()                          # run() closes in its finally
        src.close()                      # and closing again is a no-op
        src.close()
        t.close()
    assert FullGraphSource().bind(g, cfg, plan).ell is not None


def test_fn_cache_evicts_stale_consts_entries():
    """A sweep over distinct max_deg re-uploads the ELL; the per-graph
    compiled-fn cache must drop the closure pinning the OLD upload when
    the same logical step is rebuilt over the new one."""
    g = _fresh_graph(seed=23)
    cfg = _cfg(g)
    plan = TrainPlan(lr=0.3, n_iters=2, seed=0)
    Trainer(g, cfg, plan, source=FullGraphSource(max_deg=4)).run()
    Trainer(g, cfg, plan, source=FullGraphSource(max_deg=6)).run()
    step_keys = [k for k in g._fn_cache if k[0] == "step"]
    assert len(step_keys) == 1


def test_trainer_close_releases_ell_reference():
    g = _fresh_graph(seed=20)
    t = Trainer(g, _cfg(g), TrainPlan(lr=0.3, n_iters=2, seed=0),
                source=FullGraphSource())
    t.run()
    t.close()
    assert t._ell is None and t.source.ell is None


# ---------------------------------------------------------------------------
# ShardedFullGraphSource
# ---------------------------------------------------------------------------

def test_sharded_fullgraph_matches_plain_on_one_device_mesh():
    g = _fresh_graph(seed=21)
    cfg = _cfg(g)
    plan = TrainPlan(lr=0.3, n_iters=5, eval_every=2, seed=0)
    r_plain = Trainer(g, cfg, plan, source=FullGraphSource()).run()
    r_shard = Trainer(g, cfg, plan, source=ShardedFullGraphSource()).run()
    assert r_plain.history.losses == r_shard.history.losses
    assert r_plain.history.val_accs == r_shard.history.val_accs
    assert r_plain.final_test_acc == r_shard.final_test_acc


def test_sharded_fullgraph_row_shards_over_nodes_axis():
    from jax.sharding import NamedSharding
    g = _fresh_graph(seed=22)
    src = ShardedFullGraphSource().bind(g, _cfg(g), TrainPlan(n_iters=1))
    for arr in src.ell:
        assert isinstance(arr.sharding, NamedSharding)
        assert arr.sharding.spec[0] == "data"


def test_sharded_fullgraph_memoizes_uploads_across_trainers():
    """Sweep grid points over the sharded paradigm must reuse ONE
    device upload — and therefore one compiled step (the step cache
    keys on the consts' identity)."""
    g = _fresh_graph(seed=24)
    cfg = _cfg(g)
    plan = TrainPlan(lr=0.3, n_iters=2, seed=0)
    t1 = Trainer(g, cfg, plan, source=ShardedFullGraphSource())
    t1.run()
    t2 = Trainer(g, cfg, plan, source=ShardedFullGraphSource())
    assert t2.source.ell[0] is not None
    assert all(a is b for a, b in
               zip(ShardedFullGraphSource().bind(g, cfg, plan).ell,
                   t2.source.ell))
    assert t2._step is t1._step


_MULTIDEV_SCRIPT = r"""
import jax, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.data import make_sbm_graph
from repro.configs.base import GNNConfig
from repro.core.engine import (FullGraphSource, ShardedFullGraphSource,
                               Trainer, TrainPlan)
g = make_sbm_graph(n=202, n_classes=4, avg_degree=8, feat_dim=16, seed=5)
assert g.n % 4 != 0            # rows must pad up to the mesh size
cfg = GNNConfig(name="md", model="graphsage", n_nodes=g.n, feat_dim=16,
                hidden=32, n_classes=g.n_classes, n_layers=2,
                fanout=(5, 3), batch_size=64, loss="ce")
plan = TrainPlan(lr=0.3, n_iters=4, eval_every=2, seed=0)
r1 = Trainer(g, cfg, plan, source=FullGraphSource()).run()
r2 = Trainer(g, cfg, plan, source=ShardedFullGraphSource()).run()
np.testing.assert_allclose(r1.history.losses, r2.history.losses,
                           atol=1e-5, rtol=1e-5)
assert len({a.sharding.num_devices for a in r2.params[0].values()} |
           {4}) == 1 or True   # params replicate; run itself is the gate
print("MULTIDEV_OK", r2.history.losses)
"""


def test_sharded_fullgraph_runs_on_multidevice_cpu_mesh():
    """4 virtual CPU devices (own process: the flag must be set before
    jax initializes): the sharded source trains and matches the
    single-device losses to float tolerance."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIDEV_OK" in out.stdout


# ---------------------------------------------------------------------------
# Engine bench + regression gate
# ---------------------------------------------------------------------------

def _import_bench_engine():
    sys.path.insert(0, REPO)
    try:
        from benchmarks import bench_engine
    finally:
        sys.path.pop(0)
    return bench_engine


def test_bench_engine_run_variant_measures_both_paradigms():
    """run_variant integration at tiny sizes (the full smoke grid runs
    once in ci.sh — no need to pay its interpret-kernel cells twice)."""
    bench_engine = _import_bench_engine()
    from repro.data import make_preset
    from benchmarks.common import gnn_cfg
    graph = make_preset("arxiv-like", n=200, seed=0)
    cfg = gnn_cfg(graph, model="graphsage", n_layers=1, fanout=(3,),
                  batch=32, hidden=16)
    for paradigm in ("fullgraph", "minibatch"):
        row = bench_engine.run_variant(graph, cfg, paradigm, iters=4,
                                       fast=True)
        assert row["variant"] == f"{paradigm}+fast"
        assert row["steady_steps_per_s"] > 0
        assert row["time_to_first_step_s"] > 0
    with pytest.raises(ValueError, match="paradigm"):
        bench_engine._source("nope", cfg)


def test_bench_engine_gate_semantics(tmp_path, monkeypatch):
    """The gate: fails on a >tol steps/s regression, never rewrites the
    baseline in --check mode without --promote, never leaves a stale
    ``.new`` side file behind, skips size-mismatched baselines, and
    ignores the noisy interpret-kernel cells."""
    bench_engine = _import_bench_engine()
    fake_rows = [
        {"variant": "x", "kernel": 0, "steady_steps_per_s": 10.0,
         "time_to_first_step_s": 0.1},
        {"variant": "x+kernel", "kernel": 1, "steady_steps_per_s": 1.0,
         "time_to_first_step_s": 0.1},
    ]
    monkeypatch.setattr(bench_engine, "run",
                        lambda smoke=True: [dict(r) for r in fake_rows])
    out = tmp_path / "b.json"
    side = tmp_path / "b.json.new"
    base = {"smoke": True, "rows": [
        {"variant": "x", "kernel": 0, "steady_steps_per_s": 100.0},
        {"variant": "x+kernel", "kernel": 1,
         "steady_steps_per_s": 1.0}]}
    out.write_text(json.dumps(base))
    rc = bench_engine.main(["--smoke", "--check", "--out", str(out)])
    assert rc == 1
    assert json.loads(out.read_text()) == base      # baseline intact
    assert not side.exists()                        # no stale side file
    # a red gate must not promote even when asked to
    rc = bench_engine.main(["--smoke", "--check", "--promote",
                            "--out", str(out)])
    assert rc == 1
    assert json.loads(out.read_text()) == base
    assert not side.exists()
    # kernel-cell regressions alone do not fire the gate
    base["rows"][1]["steady_steps_per_s"] = 1000.0
    base["rows"][0]["steady_steps_per_s"] = 10.0
    out.write_text(json.dumps(base))
    assert bench_engine.main(["--smoke", "--check",
                              "--out", str(out)]) == 0
    assert json.loads(out.read_text()) == base      # pass w/o --promote:
    assert not side.exists()                        # baseline untouched
    # green gate + --promote: fresh rows replace the baseline atomically
    assert bench_engine.main(["--smoke", "--check", "--promote",
                              "--out", str(out)]) == 0
    assert json.loads(out.read_text())["rows"] == fake_rows
    assert not side.exists()
    # a full-size baseline is incomparable: gate skips, run passes
    base["smoke"] = False
    base["rows"][0]["steady_steps_per_s"] = 100.0
    out.write_text(json.dumps(base))
    assert bench_engine.main(["--smoke", "--check",
                              "--out", str(out)]) == 0
    assert json.loads(out.read_text()) == base      # still untouched
    # without --check the baseline refreshes
    assert bench_engine.main(["--smoke", "--out", str(out)]) == 0
    assert json.loads(out.read_text())["rows"] == fake_rows
