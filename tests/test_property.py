"""Hypothesis property tests on system invariants (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro import sharding as sh
from repro.core.graph import norm_coef
from repro.core.metrics import History, iteration_to_loss
from repro.optim import adamw, sgd, clip_by_global_norm

SETTINGS = dict(max_examples=25, deadline=None)


@given(n=st.integers(1, 10_000), m=st.integers(1, 64))
@settings(**SETTINGS)
def test_pad_to_properties(n, m):
    p = sh.pad_to(n, m)
    assert p >= n and p % m == 0 and p - n < m


@given(n=st.integers(1, 512))
@settings(**SETTINGS)
def test_padded_heads_invariants(n):
    p = sh.padded_heads(n)
    assert p >= n
    assert p % sh.MODEL_PAR == 0 or p < sh.MODEL_PAR
    if n % sh.MODEL_PAR == 0:
        assert p == n


@given(st.lists(st.floats(0.01, 10.0), min_size=2, max_size=30),
       st.floats(0.001, 5.0))
@settings(**SETTINGS)
def test_clip_by_global_norm(vals, max_norm):
    g = {"a": jnp.asarray(vals, jnp.float32)}
    clipped, gn = clip_by_global_norm(g, max_norm)
    out_norm = float(jnp.linalg.norm(clipped["a"]))
    assert out_norm <= max_norm * (1 + 1e-4) + 1e-6
    if float(gn) <= max_norm:                 # no-op when under the bound
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-6)


@given(st.floats(0.01, 0.3))
@settings(max_examples=10, deadline=None)
def test_sgd_matches_closed_form(lr):
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.25])}
    opt = sgd(lr)
    new, _ = opt.update(grads, opt.init(params), params)
    np.testing.assert_allclose(
        np.asarray(new["w"]),
        np.asarray(params["w"]) - lr * np.asarray(grads["w"]), rtol=1e-6)


def test_adamw_descends_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 40))
@settings(max_examples=15, deadline=None)
def test_norm_coef_bounds(seed, deg):
    """ã entries lie in (0, 1] and decrease with degree (paper Ã def)."""
    from repro.data import make_sbm_graph
    g = make_sbm_graph(n=60, n_classes=3, avg_degree=deg % 20 + 2,
                       feat_dim=4, seed=seed % 97)
    rows = np.repeat(np.arange(g.n), 2)[:20].astype(np.int64)
    cols = np.roll(rows, 1)
    w = norm_coef(g, rows, cols)
    assert (w > 0).all() and (w <= 1.0).all()


@given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=50),
       st.floats(0.0, 10.0))
@settings(**SETTINGS)
def test_iteration_to_loss_definition(losses, target):
    h = History(losses=list(losses))
    it = iteration_to_loss(h, target)
    if it is None:
        assert all(l > target for l in losses)
    else:
        assert losses[it - 1] <= target
        assert all(l > target for l in losses[:it - 1])


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip(seed):
    import tempfile
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32),
            "b": [jnp.asarray(rng.integers(0, 5, 7), jnp.int32),
                  {"c": jnp.asarray(rng.normal(size=2), jnp.float32)}]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, seed % 7, tree)
        back = restore_checkpoint(d, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@given(st.sampled_from(["ce", "mse"]), st.integers(2, 6))
@settings(max_examples=8, deadline=None)
def test_gnn_loss_nonnegative(kind, k):
    from repro.core.gnn import gnn_loss
    rng = np.random.default_rng(k)
    logits = jnp.asarray(rng.normal(size=(10, k)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, k, 10), jnp.int32)
    l = float(gnn_loss(logits, labels, kind, k))
    assert l >= 0.0 and np.isfinite(l)
