"""Shared benchmark utilities: runs, sweeps, CSV output.

Everything routes through the unified engine (`repro.core.engine`):
``run_minibatch`` / ``run_fullgraph`` build a TrainPlan + BatchSource and
call ``Trainer.run()``; grid-shaped benchmarks can use
``repro.core.experiment.sweep`` directly (re-exported here).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence

from repro.configs.base import GNNConfig
from repro.core.engine import (FullGraphSource, SampledSource, Trainer,
                               TrainPlan)
from repro.core.experiment import (metrics_row, run_experiment,  # noqa: F401
                                   save_rows, sweep)
from repro.data import make_preset  # noqa: F401 (re-export for benches)

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

# tuned learning rates per loss (the paper tunes lr per setting; App. N)
LR = {"ce": 0.3, "mse": 0.05}


def gnn_cfg(graph, model="graphsage", n_layers=1, loss="ce",
            fanout=(10,), batch=256, hidden=64) -> GNNConfig:
    return GNNConfig(name="bench", model=model, n_nodes=graph.n,
                     feat_dim=graph.feats.shape[1], hidden=hidden,
                     n_classes=graph.n_classes, n_layers=n_layers,
                     fanout=tuple(fanout), batch_size=batch, loss=loss)


def run_minibatch(graph, cfg, b, fanouts, iters, seed=0, eval_every=10):
    plan = TrainPlan(lr=LR[cfg.loss], n_iters=iters, eval_every=eval_every,
                     seed=seed)
    t0 = time.perf_counter()
    res = Trainer(graph, cfg, plan,
                  source=SampledSource(batch_size=b, fanouts=fanouts)).run()
    return res, time.perf_counter() - t0


def run_fullgraph(graph, cfg, iters, seed=0, eval_every=10):
    plan = TrainPlan(lr=LR[cfg.loss], n_iters=iters, eval_every=eval_every,
                     seed=seed)
    t0 = time.perf_counter()
    res = Trainer(graph, cfg, plan, source=FullGraphSource()).run()
    return res, time.perf_counter() - t0


def summarize(res: "TrainResult", target_loss: Optional[float] = None,
              target_acc: Optional[float] = None) -> Dict:
    """One metric row — the experiment module's shared schema."""
    return metrics_row(res, target_loss, target_acc)


def write_csv(name: str, rows: List[Dict]) -> str:
    """CSV (+ JSON sibling) via the experiment module's writer."""
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if rows:
        path = save_rows(name, rows, out_dir=OUT_DIR)["csv"]
    return path


def print_rows(name: str, rows: Sequence[Dict]):
    for r in rows:
        kv = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{kv}", flush=True)
