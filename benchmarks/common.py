"""Shared benchmark utilities: runs, sweeps, CSV output."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import GNNConfig
from repro.core.metrics import (History, iteration_to_accuracy,
                                iteration_to_loss, throughput_nodes_per_sec,
                                time_to_accuracy)
from repro.core.trainer import train_full_graph, train_minibatch
from repro.data import make_preset

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")

# tuned learning rates per loss (the paper tunes lr per setting; App. N)
LR = {"ce": 0.3, "mse": 0.05}


def gnn_cfg(graph, model="graphsage", n_layers=1, loss="ce",
            fanout=(10,), batch=256, hidden=64) -> GNNConfig:
    return GNNConfig(name="bench", model=model, n_nodes=graph.n,
                     feat_dim=graph.feats.shape[1], hidden=hidden,
                     n_classes=graph.n_classes, n_layers=n_layers,
                     fanout=tuple(fanout), batch_size=batch, loss=loss)


def run_minibatch(graph, cfg, b, fanouts, iters, seed=0, eval_every=10):
    t0 = time.perf_counter()
    res = train_minibatch(graph, cfg, lr=LR[cfg.loss], n_iters=iters,
                          batch_size=b, fanouts=fanouts, seed=seed,
                          eval_every=eval_every)
    return res, time.perf_counter() - t0


def run_fullgraph(graph, cfg, iters, seed=0, eval_every=10):
    t0 = time.perf_counter()
    res = train_full_graph(graph, cfg, lr=LR[cfg.loss], n_iters=iters,
                           seed=seed, eval_every=eval_every)
    return res, time.perf_counter() - t0


def summarize(res: "TrainResult", target_loss: Optional[float] = None,
              target_acc: Optional[float] = None) -> Dict:
    h = res.history
    out = {
        "first_loss": round(h.losses[0], 4),
        "final_loss": round(h.losses[-1], 4),
        "test_acc": round(res.final_test_acc, 4),
        "iters": len(h.losses),
    }
    if target_loss is not None:
        out["iter_to_loss"] = iteration_to_loss(h, target_loss)
    if target_acc is not None:
        out["iter_to_acc"] = iteration_to_accuracy(h, target_acc)
        out["time_to_acc"] = time_to_accuracy(h, target_acc)
    out["throughput_nodes_s"] = round(throughput_nodes_per_sec(h), 1)
    return out


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    if rows:
        keys: List[str] = []
        for r in rows:
            for k in r:
                if k not in keys:
                    keys.append(k)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys, restval="")
            w.writeheader()
            w.writerows(rows)
    return path


def print_rows(name: str, rows: Sequence[Dict]):
    for r in rows:
        kv = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{kv}", flush=True)
