"""Sampler micro-bench: seed per-node-loop sampler vs vectorized CSR
sampler (+ the prefetch pipeline) on the seed synthetic graph presets.

The paper's throughput comparison (§5, Fig. 6) charges the mini-batch
paradigm for CPU-side sampling; this bench tracks the speedup of the
batched-index-arithmetic sampler over the seed per-node `rng.choice`
loop (target: >= 20x) and the prefetcher's overlap win.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.core.prefetch import Prefetcher
from repro.core.sampler import (expand_batch, sample_batch,
                                sample_neighbors, sample_neighbors_loop)
from repro.data import make_preset


def _time_pair(fn_a, fn_b, reps, warmup=1):
    """Best-of-reps for two competitors, INTERLEAVED so slow drift in
    machine load hits both sides equally instead of biasing the ratio."""
    for _ in range(warmup):
        fn_a()
        fn_b()
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def run(quick: bool = True, seed: int = 0):
    cases = [("arxiv-like", 512, (15, 10)),
             ("products-like", 512, (15, 10)),
             ("papers-like", 512, (15, 10)),
             ("reddit-like", 512, (15, 10))]
    if quick:
        cases = [("arxiv-like", 512, (15, 10)),
                 ("papers-like", 512, (15, 10))]
    reps = 5 if quick else 7
    rows = []
    for preset, b, fanouts in cases:
        graph = make_preset(preset, seed=seed)
        rng = np.random.default_rng(seed)
        targets = rng.choice(graph.train_nodes, size=min(
            b, len(graph.train_nodes)), replace=False).astype(np.int32)

        # --- the replaced component: per-hop neighbor sampling over the
        # fan-out tree frontiers (hop d samples b*f1*...*fd source nodes)
        frontiers = [targets]
        r0 = np.random.default_rng(seed + 1)
        for beta in fanouts[:-1]:
            nb, _ = sample_neighbors(r0, graph, frontiers[-1], beta)
            frontiers.append(nb)

        def sample_all(sampler):
            r = np.random.default_rng(seed + 2)
            for beta, fr in zip(fanouts, frontiers):
                sampler(r, graph, fr, beta)

        t_loop, t_vec = _time_pair(
            lambda: sample_all(sample_neighbors_loop),
            lambda: sample_all(sample_neighbors), reps)

        # --- end-to-end batch expansion (adds the ã-weight computation,
        # identical in both paths) for context
        def expand(sampler):
            expand_batch(np.random.default_rng(seed + 1), graph, targets,
                         fanouts, neighbor_sampler=sampler)

        t_exp_loop, t_exp_vec = _time_pair(
            lambda: expand(sample_neighbors_loop),
            lambda: expand(sample_neighbors), reps)

        # prefetch pipeline: batches/s with the host work on a thread
        n_batches = 6 if quick else 12
        with Prefetcher(graph, b, fanouts, seed=seed,
                        n_batches=n_batches) as pf:
            pf.next()                       # warm the pipeline
            t0 = time.perf_counter()
            got = 1
            for _ in range(n_batches - 1):
                pf.next()
                got += 1
            t_pf = (time.perf_counter() - t0) / max(got - 1, 1)

        rows.append({
            "preset": preset, "b": b, "fanouts": "x".join(map(str, fanouts)),
            "loop_ms": round(t_loop * 1e3, 2),
            "vec_ms": round(t_vec * 1e3, 2),
            "speedup": round(t_loop / t_vec, 1),
            "expand_loop_ms": round(t_exp_loop * 1e3, 2),
            "expand_vec_ms": round(t_exp_vec * 1e3, 2),
            "expand_speedup": round(t_exp_loop / t_exp_vec, 1),
            "prefetch_batch_ms": round(t_pf * 1e3, 2),
        })
    write_csv("sampler_microbench", rows)
    print_rows("sampler", rows)
    worst = min(r["speedup"] for r in rows)
    print(f"sampler,min_speedup={worst}x (target >= 20x)", flush=True)
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
