"""Fig. 3 / Thm 3: test accuracy of one-layer GraphSAGE (MSE) across batch
sizes and fan-out sizes (products-like + reddit-like presets).

Validates Remark 4.1 (larger b or β -> better generalization, with
possible degradation at the extremes) and Obs.2 (β moves accuracy more
than b)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import gnn_cfg, print_rows, run_minibatch, \
    summarize, write_csv
from repro.data import make_preset


def run(quick: bool = True, seed: int = 0):
    rows = []
    iters = 150 if quick else 400
    for preset in ("products-like", "reddit-like"):
        graph = make_preset(preset, seed=seed, n=1600 if quick else 4000,
                            homophily=0.6, feat_scale=0.35, train_frac=0.3)
        for loss in ("mse", "ce"):
            cfg = gnn_cfg(graph, n_layers=1, loss=loss)
            for b in [32, 128, 512, len(graph.train_nodes)]:
                res, _ = run_minibatch(graph, cfg, b, (10,), iters,
                                       seed=seed)
                rows.append({"preset": preset, "loss": loss,
                             "sweep": "batch", "b": b, "beta": 10,
                             **summarize(res)})
            for beta in [1, 2, 5, 10, min(25, graph.d_max)]:
                res, _ = run_minibatch(graph, cfg, 128, (beta,), iters,
                                       seed=seed)
                rows.append({"preset": preset, "loss": loss,
                             "sweep": "fanout", "b": 128, "beta": beta,
                             **summarize(res)})
    write_csv("fig3_generalization", rows)
    print_rows("fig3", rows)
    return rows


if __name__ == "__main__":
    run()
