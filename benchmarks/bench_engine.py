"""Engine throughput bench: steady-state steps/s and time-to-first-step
for BOTH training paradigms, toggling the device-resident fast path —
Pallas aggregation kernel on/off, params/opt_state donation + deferred
loss sync on/off, the scenario sources, and (``--devices N``) the
NODES-sharded sources on a multi-device mesh.  An ``inference`` variant
family benchmarks the serving tier: layer-wise embedding build
(ms/node, chunk steps/s) and micro-batched query throughput per
aggregation path, ``@Ndev``-keyed like the training rows.

``--devices N`` reruns the SHARDED variant set (fullgraph_sharded /
minibatch_sharded, einsum + shard_map'd kernel cells) in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the flag
must be set before jax initializes, so the parent process cannot host
them.  Multi-device rows are keyed by a ``@Ndev`` variant suffix, so
they land BESIDE the 1-device baseline rows instead of on top of them.

Writes ``BENCH_engine.json`` at the REPO ROOT so every subsequent PR has
a perf trajectory to regress against.  ``--check`` (CI mode) compares
fresh numbers to the committed baseline and fails with a readable
per-variant diff when steady-state steps/s regresses more than
``BENCH_TOL`` (default 25%); in that mode the baseline is only replaced
when ``--promote`` is given AND the gate passes (atomic tmp+rename via
``BENCH_engine.json.new``) — otherwise the side file is deleted before
exit, so repeated local runs cannot ratchet the bar down and CI leaves
the tree clean (``make bench-promote`` wraps the refresh).
Interpret-mode kernel cells and ``inference`` rows are recorded but
excluded from the gate (their few-iteration CPU wall-clock is noise —
a smoke embedding build is ~8 sub-ms chunk dispatches); a baseline
recorded at a
different size class (smoke vs full) is skipped as incomparable.

    python benchmarks/bench_engine.py --smoke --check --devices 4  # CI gate
    python benchmarks/bench_engine.py --smoke --devices 4  # refresh baseline
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
from typing import Dict, List, Optional

import jax

from benchmarks.common import gnn_cfg, print_rows
from repro.core.engine import Trainer, TrainPlan
from repro.core.experiment import make_source
from repro.data import make_preset

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(REPO_ROOT, "BENCH_engine.json")


def _source(paradigm: str, cfg):
    """Engine's paradigm dispatch, parameterized from the bench cfg."""
    return make_source(paradigm, b=cfg.batch_size, fanouts=cfg.fanout)


def run_variant(graph, cfg, paradigm: str, iters: int, fast: bool,
                seed: int = 0, repeats: int = 1) -> Dict:
    """One (paradigm, kernel, fast-path) cell: time-to-first-step is the
    History timestamp of iteration 0 of the FIRST run (compile + first
    dispatch + sync); steady-state steps/s is the BEST of ``repeats``
    runs — later runs reuse the cached compiled step, and taking the
    least-loaded measurement keeps the CI gate from firing on transient
    host contention."""
    plan = TrainPlan(lr=0.3, n_iters=iters, eval_every=10 ** 9, seed=seed,
                     donate=fast, deferred_sync=fast)
    ttfs, steady, res = 0.0, 0.0, None
    for rep in range(max(repeats, 1)):
        trainer = Trainer(graph, cfg, plan, source=_source(paradigm, cfg))
        try:
            res = trainer.run()
        finally:
            trainer.close()
        times = res.history.times
        if rep == 0:
            ttfs = times[0]
        steady = max(steady,
                     (len(times) - 1) / (times[-1] - times[0])
                     if len(times) > 1 and times[-1] > times[0] else 0.0)
    n_dev = len(jax.devices())
    featshard = cfg.feats_layout == "sharded"
    row = {
        # multi-device runs key their variants by device count, so a
        # 4-device row diffs against the 4-device baseline row — never
        # against (or over) the 1-device one
        "variant": f"{paradigm}"
                   f"{'+kernel' if cfg.use_agg_kernel else ''}"
                   f"{'+featshard' if featshard else ''}"
                   f"{'+fast' if fast else ''}"
                   f"{f'@{n_dev}dev' if n_dev > 1 else ''}",
        "paradigm": paradigm,
        "kernel": int(cfg.use_agg_kernel),
        "fast_path": int(fast),          # donation + deferred loss sync
        "devices": len(jax.devices()),
        "iters": iters,
        "time_to_first_step_s": round(ttfs, 4),
        "steady_steps_per_s": round(steady, 2),
        "final_loss": round(res.history.losses[-1], 6),
    }
    if featshard:
        # the hot-cache accounting the sources surface at train end:
        # full-graph plans report bind-time classification, sampled
        # sources report the host LRU — either way the same keys
        c = res.history.counters
        row["cache_hit_rate"] = round(c.get("feat_cache_hit_rate", 0.0), 4)
        row["remote_gather_bytes"] = int(c.get("feat_remote_gather_bytes",
                                               0))
        row["table_bytes_per_device"] = int(
            c.get("feat_table_bytes_per_device", 0))
    return row


def run_inference_variant(graph, cfg, seed: int = 0, repeats: int = 2,
                          mesh=None, chunk_size: int = 128,
                          serve_requests: int = 128) -> Dict:
    """One inference-tier cell: layer-wise embedding build (ms/node;
    "steps" are chunk dispatches, so ``steady_steps_per_s`` keeps the
    gate's shared row schema) plus micro-batched serve throughput
    (queries/s through ``GNNServer``).  ``time_to_first_step_s`` is the
    FIRST build (compile included); steady-state comes from the best of
    the warm rebuilds."""
    import numpy as np

    from repro.core import gnn as G
    from repro.core.embedding_store import EmbeddingStore
    from repro.core.serving import GNNServer

    params = G.init_gnn(jax.random.key(seed), cfg, graph.feats.shape[1])
    ttfs, steady, store, stats = 0.0, 0.0, None, None
    for rep in range(max(repeats, 1)):
        s = EmbeddingStore(params, cfg, graph, chunk_size=chunk_size,
                           mesh=mesh)
        run = s.build()
        rate = run.stats["chunk_steps"] / max(run.stats["total_s"], 1e-9)
        if rep == 0:
            ttfs = run.stats["total_s"]
        if rep > 0 or repeats == 1:
            steady = max(steady, rate)
        store, stats = s, run.stats
    rng = np.random.default_rng(seed)
    server = GNNServer(store, max_batch=32, max_wait_ms=0.5)
    try:
        futs = [server.submit(rng.integers(0, graph.n, size=8))
                for _ in range(serve_requests)]
        for f in futs:
            f.result(timeout=120.0)
    finally:
        server.close()
    st = server.stats()
    n_dev = len(jax.devices())
    return {
        "variant": f"inference"
                   f"{'+kernel' if cfg.use_agg_kernel else ''}"
                   f"{f'@{n_dev}dev' if n_dev > 1 else ''}",
        "paradigm": "inference",
        "kernel": int(cfg.use_agg_kernel),
        "fast_path": 1,
        "devices": n_dev,
        "iters": stats["chunk_steps"],
        "time_to_first_step_s": round(ttfs, 4),
        "steady_steps_per_s": round(steady, 2),
        "ms_per_node": round(stats["ms_per_node"], 5),
        "serve_q_per_s": round(st["qps"], 1),
        "serve_p99_ms": round(st["p99_ms"], 4),
    }


def run_serve_writes_variant(graph, cfg, seed: int = 0,
                             serve_requests: int = 128,
                             n_updates: int = 24,
                             chunk_size: int = 128) -> Dict:
    """Serving under write load (PR 10): a background writer streams
    feature updates through the WAL while query clients hammer the
    server; the row records answered queries/s, p99 latency, the max
    served staleness and the refresh-budget accounting (scheduler vs
    SLO-forced refreshes).  ``paradigm="inference"`` keeps the row
    recorded-but-not-gated, like the other inference cells — wall-clock
    under a concurrent writer is even noisier than the build loop."""
    import threading
    import time as _time

    import numpy as np

    from repro.core import gnn as G
    from repro.core.embedding_store import EmbeddingStore
    from repro.core.serving import GNNServer

    params = G.init_gnn(jax.random.key(seed), cfg, graph.feats.shape[1])
    store = EmbeddingStore(params, cfg, graph, chunk_size=chunk_size)
    run = store.build()
    rng = np.random.default_rng(seed)
    server = GNNServer(store, max_batch=32, max_wait_ms=0.5,
                       max_staleness_s=0.25, refresh_every_updates=4,
                       refresh_budget_ms=50.0)
    t0 = _time.monotonic()
    try:
        def writer():
            for _ in range(n_updates):
                nodes = rng.choice(graph.n, size=4, replace=False)
                store.update_features(
                    nodes, rng.normal(size=(4, graph.feats.shape[1]))
                    .astype(np.float32))
                _time.sleep(0.002)

        wt = threading.Thread(target=writer)
        wt.start()
        futs = [server.submit(rng.integers(0, graph.n, size=8))
                for _ in range(serve_requests)]
        for f in futs:
            f.result(timeout=120.0)
        wt.join(timeout=60.0)
    finally:
        server.close()
    total_s = _time.monotonic() - t0
    st = server.stats()
    rs = store.refresh_stats()
    n_dev = len(jax.devices())
    return {
        "variant": f"serve+writes"
                   f"{'+kernel' if cfg.use_agg_kernel else ''}"
                   f"{f'@{n_dev}dev' if n_dev > 1 else ''}",
        "paradigm": "inference",
        "kernel": int(cfg.use_agg_kernel),
        "fast_path": 1,
        "devices": n_dev,
        "iters": serve_requests,
        "time_to_first_step_s": round(run.stats["total_s"], 4),
        "steady_steps_per_s": round(serve_requests / max(total_s, 1e-9),
                                    2),
        "serve_q_per_s": round(st["qps"], 1),
        "serve_p99_ms": round(st["p99_ms"], 4),
        "staleness_max_s": round(st["staleness_max_s"], 4),
        "snapshot_version": int(st["snapshot_version"]),
        "n_updates": n_updates,
        "sched_refreshes": int(rs["sched_refreshes"]),
        "forced_refreshes": int(st["n_forced_refresh"]),
    }


def _bench_setup(smoke: bool, seed: int):
    """Shared sizes/graph/configs for the main and sharded variant sets
    (identical sizes keep 1-device and @Ndev rows comparable)."""
    # gated cells need a measurement window big enough to ride out
    # scheduler jitter on throttled CI hosts (~0.5 s per run, x3 runs)
    n, iters, kernel_iters = (400, 96, 6) if smoke else (2000, 200, 12)
    graph = make_preset("arxiv-like", n=n, seed=seed)
    cfg = gnn_cfg(graph, model="graphsage", n_layers=2, fanout=(5, 3),
                  batch=64, hidden=32)
    kcfg = dataclasses.replace(cfg, model="gcn", use_agg_kernel=True,
                               agg_interpret=True, agg_b_tile=8,
                               agg_d_tile=128, agg_k_slab=4)
    return graph, cfg, kcfg, iters, kernel_iters


def run(smoke: bool = True, seed: int = 0) -> List[Dict]:
    graph, cfg, kcfg, iters, kernel_iters = _bench_setup(smoke, seed)
    rows = []
    for paradigm in ("fullgraph", "minibatch"):
        for fast in (False, True):
            # gated cells: best-of-3 to smooth host-load noise
            rows.append(run_variant(graph, cfg, paradigm, iters, fast,
                                    seed=seed, repeats=3))
        # kernel-on cell (interpret mode on CPU: correctness + dispatch
        # shape, NOT a TPU wall-time — few iters keep it cheap, and the
        # gate skips it)
        rows.append(run_variant(graph, kcfg, paradigm, kernel_iters,
                                True, seed=seed))
    # scenario sources (one fast-path cell each): cluster unions,
    # importance-weighted targets, NODES-sharded mini-batches.
    for paradigm in ("cluster", "importance", "minibatch_sharded"):
        rows.append(run_variant(graph, cfg, paradigm, iters, True,
                                seed=seed, repeats=3))
    if len(jax.devices()) > 1:
        rows.append(run_variant(graph, cfg, "fullgraph_sharded", iters,
                                True, seed=seed, repeats=3))
    # inference tier: layer-wise embed + serve throughput, einsum
    # (gated once baselined) and Pallas-kernel (record-only) cells
    rows.append(run_inference_variant(graph, cfg, seed=seed, repeats=3))
    rows.append(run_inference_variant(graph, kcfg, seed=seed, repeats=1,
                                      serve_requests=32))
    # serving under a concurrent write stream (qps/p99/staleness —
    # recorded, not gated, like the other inference cells)
    rows.append(run_serve_writes_variant(graph, cfg, seed=seed))
    return rows


def run_sharded(smoke: bool = True, seed: int = 0) -> List[Dict]:
    """The NODES-sharded variant set — einsum fast-path cells (gated)
    plus shard_map'd Pallas kernel cells (interpret mode, record-only)
    for both sharded sources.  Meant to run under
    ``--xla_force_host_platform_device_count=N`` via ``--devices``."""
    graph, cfg, kcfg, iters, kernel_iters = _bench_setup(smoke, seed)
    # NODES-sharded feature table + degree-ordered hot cache: kernel=1
    # keeps these cells record-only (interpret mode), but their
    # cache_hit_rate / remote_gather_bytes columns ARE the bench's
    # feature-traffic trajectory
    fscfg = dataclasses.replace(kcfg, feats_layout="sharded",
                                feat_cache_rows=-1)
    rows = []
    for paradigm in ("fullgraph_sharded", "minibatch_sharded"):
        rows.append(run_variant(graph, cfg, paradigm, iters, True,
                                seed=seed, repeats=3))
        rows.append(run_variant(graph, kcfg, paradigm, kernel_iters,
                                True, seed=seed))
        rows.append(run_variant(graph, fscfg, paradigm, kernel_iters,
                                True, seed=seed))
    # layer-wise inference through the NODES-sharded kernel path
    # (record-only: kernel rows are excluded from the gate)
    from repro import sharding as sh
    rows.append(run_inference_variant(graph, kcfg, seed=seed, repeats=1,
                                      mesh=sh.node_mesh(),
                                      serve_requests=32))
    return rows


def _sharded_subprocess(n_dev: int, smoke: bool) -> List[Dict]:
    """Run ``run_sharded`` under N virtual CPU devices (the XLA flag
    must be set before jax initializes, hence the subprocess)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_dev}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO_ROOT, "src"), REPO_ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    with tempfile.NamedTemporaryFile("r", suffix=".json") as tf:
        cmd = [sys.executable, os.path.abspath(__file__), "--sharded-only",
               "--rows-out", tf.name] + (["--smoke"] if smoke else [])
        subprocess.run(cmd, env=env, check=True, timeout=3600)
        return json.load(open(tf.name))


# ---------------------------------------------------------------------------
# Baseline check
# ---------------------------------------------------------------------------

def check_regression(rows: List[Dict], baseline_path: str = BENCH_PATH,
                     tol: Optional[float] = None,
                     smoke: Optional[bool] = None) -> List[str]:
    """Readable per-variant diff vs the committed baseline; returns the
    list of failures (> tol relative steps/s regression).  A baseline
    recorded at a different size class (smoke vs full) is incomparable
    and skipped rather than silently passed."""
    tol = float(os.environ.get("BENCH_TOL", "0.25")) if tol is None else tol
    if not os.path.exists(baseline_path):
        print(f"bench_engine: no baseline at {baseline_path}, skipping "
              "regression check")
        return []
    with open(baseline_path) as f:
        payload = json.load(f)
    if smoke is not None and payload.get("smoke") != smoke:
        print(f"bench_engine: baseline at {baseline_path} was recorded "
              f"with smoke={payload.get('smoke')}, current run is "
              f"smoke={smoke} — sizes are incomparable, skipping "
              "regression check")
        return []
    n_dev = len(jax.devices())
    if payload.get("devices", n_dev) != n_dev:
        print(f"bench_engine: baseline recorded on "
              f"{payload.get('devices')} device(s), current run sees "
              f"{n_dev} — incomparable, skipping regression check")
        return []
    base = {r["variant"]: r for r in payload["rows"]}
    failures = []
    for r in rows:
        if r.get("kernel"):
            # interpret-mode kernel cells exist for correctness /
            # dispatch shape; their few-iteration CPU wall-clock is too
            # noisy to gate on
            continue
        if r.get("paradigm") == "inference":
            # a smoke embedding build is ~8 sub-ms chunk dispatches —
            # its chunk-steps/s swings >40% run to run on a shared CPU,
            # so inference rows are recorded for the perf trajectory
            # but not gated (same rationale as the kernel cells)
            print(f"  {r['variant']:32s} steps/s "
                  f"{r['steady_steps_per_s']:>10.2f} (inference row — "
                  f"recorded, not gated)")
            continue
        b = base.get(r["variant"])
        if b is None:
            # a variant the baseline predates (e.g. a source added in
            # this PR): record-only until the baseline is refreshed —
            # the first PR after a new source must not trip the gate
            print(f"  {r['variant']:32s} steps/s "
                  f"{r['steady_steps_per_s']:>10.2f} (new variant, not "
                  f"in baseline — not gated)")
            continue
        if not b["steady_steps_per_s"]:
            continue
        old, new = b["steady_steps_per_s"], r["steady_steps_per_s"]
        rel = (new - old) / old
        line = (f"  {r['variant']:32s} steps/s {old:10.2f} -> {new:10.2f} "
                f"({rel:+.1%})")
        print(line)
        if rel < -tol:
            failures.append(line)
    if failures:
        print(f"bench_engine: steady-state steps/s regressed more than "
              f"{tol:.0%} vs {baseline_path}:")
        for line in failures:
            print("FAIL" + line)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for per-PR CI")
    ap.add_argument("--check", action="store_true",
                    help="fail on >BENCH_TOL steps/s regression vs the "
                         "committed BENCH_engine.json")
    ap.add_argument("--promote", action="store_true",
                    help="with --check: when the gate passes, atomically "
                         "replace the committed baseline with the fresh "
                         "numbers (tmp file + rename); without this flag "
                         "--check never touches the baseline")
    ap.add_argument("--devices", type=int, default=0,
                    help="additionally run the sharded variant set in a "
                         "subprocess with N virtual CPU devices "
                         "(rows keyed @Ndev beside the 1-device ones)")
    ap.add_argument("--sharded-only", action="store_true",
                    help=argparse.SUPPRESS)    # the --devices subprocess
    ap.add_argument("--rows-out", default="", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=BENCH_PATH,
                    help="output path (default: repo-root "
                         "BENCH_engine.json)")
    args = ap.parse_args(argv)

    if args.sharded_only:
        rows = run_sharded(smoke=args.smoke)
        print_rows("engine-sharded", rows)
        if args.rows_out:
            with open(args.rows_out, "w") as f:
                json.dump(rows, f, indent=1)
        return 0

    rows = run(smoke=args.smoke)
    if args.devices > 1 and len(jax.devices()) == 1:
        # only from a 1-device parent: a multi-device parent already
        # recorded in-process sharded rows under the same @Ndev keys,
        # and a forced-CPU subprocess duplicate would silently win the
        # per-variant dict in the gate/baseline
        rows += _sharded_subprocess(args.devices, args.smoke)
    elif args.devices:
        print(f"bench_engine: --devices {args.devices} skipped "
              f"(parent already sees {len(jax.devices())} device(s); "
              "sharded rows come from the in-process run)")
    print_rows("engine", rows)
    payload = {"bench": "engine", "smoke": bool(args.smoke),
               "devices": len(jax.devices()), "rows": rows}
    if args.check:
        # gate mode never silently rewrites the baseline (no ratchet):
        # fresh numbers go to a side file, which either gets PROMOTED
        # over the baseline via an atomic same-directory rename
        # (--promote, gate green) or is deleted before exit — CI and
        # repeated local runs leave the tree clean either way
        failures = check_regression(rows, baseline_path=args.out,
                                    smoke=bool(args.smoke))
        side = args.out + ".new"
        try:
            with open(side, "w") as f:
                json.dump(payload, f, indent=1)
                f.write("\n")
            if args.promote and not failures:
                os.replace(side, args.out)   # atomic: tmp + rename
                print(f"bench_engine: gate passed — promoted fresh "
                      f"numbers to {args.out}")
            elif args.promote:
                print(f"bench_engine: gate FAILED — baseline {args.out} "
                      "left untouched despite --promote")
            else:
                print(f"bench_engine: baseline {args.out} untouched in "
                      "--check mode (pass --promote to refresh it on a "
                      "green gate)")
        finally:
            if os.path.exists(side):
                os.remove(side)
        return 1 if failures else 0
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"bench_engine: wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
