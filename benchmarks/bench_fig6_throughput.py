"""Fig. 6(c,d) / §5.4: training throughput (target nodes/s) across batch
and fan-out sizes — computational-efficiency claims: throughput rises
with b, falls with β; mini-batch beats full-graph per-node."""
from __future__ import annotations

from benchmarks.common import gnn_cfg, print_rows, run_fullgraph, \
    run_minibatch, summarize, write_csv
from repro.data import make_preset


def run(quick: bool = True, seed: int = 0):
    graph = make_preset("products-like", seed=seed,
                        n=1600 if quick else 4000)
    iters = 60 if quick else 150
    rows = []
    cfg = gnn_cfg(graph, n_layers=1, loss="ce")
    for b in [32, 128, 512, len(graph.train_nodes)]:
        res, wall = run_minibatch(graph, cfg, b, (10,), iters, seed=seed,
                                  eval_every=10 ** 9)
        rows.append({"sweep": "batch", "b": b, "beta": 10,
                     **summarize(res), "wall_s": round(wall, 2)})
    for beta in [2, 5, 10, 20]:
        res, wall = run_minibatch(graph, cfg, 128, (beta,), iters,
                                  seed=seed, eval_every=10 ** 9)
        rows.append({"sweep": "fanout", "b": 128, "beta": beta,
                     **summarize(res), "wall_s": round(wall, 2)})
    res, wall = run_fullgraph(graph, cfg, iters, seed=seed,
                              eval_every=10 ** 9)
    rows.append({"sweep": "fullgraph", "b": len(graph.train_nodes),
                 "beta": graph.d_max, **summarize(res),
                 "wall_s": round(wall, 2)})
    write_csv("fig6_throughput", rows)
    print_rows("fig6", rows)
    return rows


if __name__ == "__main__":
    run()
