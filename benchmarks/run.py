"""Benchmark harness — one module per paper table/figure (deliverable d).

    PYTHONPATH=src python -m benchmarks.run            # quick defaults
    PYTHONPATH=src python -m benchmarks.run --full
    PYTHONPATH=src python -m benchmarks.run --only fig2,table1

CSV outputs land in experiments/bench/.
"""
from __future__ import annotations

import argparse
import time
import traceback

BENCHES = [
    ("fig1_metric_stability", "benchmarks.bench_fig1_metric_stability"),
    ("fig2_convergence", "benchmarks.bench_fig2_convergence"),
    ("fig3_generalization", "benchmarks.bench_fig3_generalization"),
    ("fig4_multilayer", "benchmarks.bench_fig4_multilayer"),
    ("fig5_iter_to_acc", "benchmarks.bench_fig5_iter_to_acc"),
    ("fig6_throughput", "benchmarks.bench_fig6_throughput"),
    ("table1_tuned", "benchmarks.bench_table1_tuned"),
    ("thm3_wasserstein", "benchmarks.bench_thm3_wasserstein"),
    ("theory_slopes", "benchmarks.bench_theory_slopes"),
    ("kernel_microbench", "benchmarks.bench_kernel"),
    ("roofline_report", "benchmarks.roofline_report"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slow)")
    ap.add_argument("--only", default="",
                    help="comma-separated substring filters")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    results = {}
    for name, mod_name in BENCHES:
        if only and not any(s in name for s in only):
            continue
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            rows = mod.run(quick=not args.full)
            results[name] = ("ok", len(rows), time.time() - t0)
        except Exception as e:  # noqa
            traceback.print_exc()
            results[name] = ("error", str(e)[:100], time.time() - t0)
        print(f"== {name}: {results[name]}", flush=True)

    print("\n=== benchmark summary ===")
    for name, r in results.items():
        print(f"{name:24s} {r}")
    if any(r[0] == "error" for r in results.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
