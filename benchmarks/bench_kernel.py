"""Neighbor-aggregation kernel micro-bench: jnp oracle vs Pallas
(interpret mode on CPU — correctness + working-set accounting; wall time
is NOT a TPU number, the derived bytes/flops are hardware-independent)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.kernels.neighbor_agg.ops import neighbor_agg


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    cases = [(4096, 128, 256, 15), (16384, 256, 512, 10)]
    if quick:
        cases = [(1024, 128, 64, 15)]
    for n, d, b, k in cases:
        feats = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
        w = jnp.asarray(rng.random((b, k)), jnp.float32)
        ref = neighbor_agg(feats, idx, w, use_kernel=False)
        ref.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            neighbor_agg(feats, idx, w, use_kernel=False).block_until_ready()
        t_ref = (time.perf_counter() - t0) / 3
        ker = neighbor_agg(feats, idx, w, use_kernel=True, interpret=True)
        err = float(jnp.max(jnp.abs(ref - ker)))
        flops = 2.0 * b * k * d
        bytes_moved = (b * k * (d * 4 + 4 + 4) + b * d * 4)
        rows.append({
            "n": n, "d": d, "b": b, "k": k,
            "jnp_us_per_call": round(t_ref * 1e6, 1),
            "kernel_max_err": err,
            "flops": int(flops),
            "bytes_moved": int(bytes_moved),
            "arithmetic_intensity": round(flops / bytes_moved, 3),
            "v5e_hbm_bound_us": round(bytes_moved / 819e9 * 1e6, 3),
        })
    write_csv("kernel_microbench", rows)
    print_rows("kernel", rows)
    return rows


if __name__ == "__main__":
    run()
