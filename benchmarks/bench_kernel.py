"""Neighbor-aggregation kernel micro-bench: jnp oracle vs Pallas row
kernel vs batch-tiled kernel (interpret mode on CPU — correctness +
working-set accounting; wall time is NOT a TPU number, the derived
bytes/flops are hardware-independent).

bytes accounting (fix for the seed formula, which charged one row-DMA
plus 4+4 id/weight bytes per (b, k) pair regardless of tiling):

* feature rows: every kernel moves b*k*d*itemsize feature bytes HBM->VMEM
  (one row tile per (b, k, d_tile) triple — gathers don't dedupe).
* ids: scalar-prefetched ONCE per call (b*k*4), both kernels.
* weights: re-fetched per d-tile pass.  The row kernel issues a (1, 1)
  block load per (b, d_tile, k) step — HBM reads have a minimum DMA
  granularity, so each scalar load costs a full `_DMA_GRAIN` line.  The
  tiled kernel loads one contiguous (b_tile, k_slab) block per step,
  amortizing the grain across b_tile*k_slab weights.
* output: written once (the accumulator lives in VMEM), b*d*itemsize.

exposed-wait accounting (the double-buffering win): a "serialized DMA
wait" is a kernel step that must stall on HBM with no compute to hide
behind.  The row kernel waits its single row DMA EVERY grid step.  The
tiled kernel double-buffers K-slabs across the sequential K grid axis,
so only the FIRST slab of each (b_tile, d_tile) output tile is exposed;
the other K/k_slab - 1 slab waits overlap the previous slab's FMAs.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.analysis.pallas_audit import row_agg_budget, tiled_agg_budget
from repro.kernels.neighbor_agg.ops import neighbor_agg

_DMA_GRAIN = 32          # min HBM read granularity per distinct load, bytes

# one set of tile constants feeds BOTH the kernel invocation and the
# bytes accounting, so retuning can't silently desync them
B_TILE, D_TILE, K_SLAB = 8, 128, 4

# per-step VMEM working set from the SAME budget model `make analyze`
# gates against the backend limit (analysis/pallas_audit.py) — keeping
# the bench and the checker on one formula
_VMEM_BYTES = {
    "row": sum(row_agg_budget(D_TILE).values()),
    "tiled": sum(tiled_agg_budget(B_TILE, D_TILE, K_SLAB).values()),
}


def _accounting(kernel, n, d, b, k, itemsize=4,
                b_tile=B_TILE, d_tile=D_TILE, k_slab=K_SLAB):
    d_pad = -(-d // d_tile) * d_tile
    d_passes = d_pad // d_tile
    feat_bytes = b * k * d_pad * itemsize
    idx_bytes = b * k * 4
    out_bytes = b * d_pad * itemsize
    if kernel == "row":
        grid_steps = b * d_passes * k
        w_loads = grid_steps                      # one (1,1) block per step
        w_bytes = w_loads * _DMA_GRAIN
        dmas_per_step = 1
        # no pipelining: every step stalls on its own row DMA
        exposed_waits = grid_steps
    else:
        b_pad = -(-b // b_tile) * b_tile
        k_pad = -(-k // k_slab) * k_slab
        feat_bytes = b_pad * k_pad * d_pad * itemsize
        idx_bytes = b_pad * k_pad * 4
        out_bytes = b_pad * d_pad * itemsize
        grid_steps = (b_pad // b_tile) * d_passes * (k_pad // k_slab)
        w_loads = grid_steps                      # one (b_tile,k_slab) block
        w_bytes = w_loads * max(b_tile * k_slab * 4, _DMA_GRAIN)
        dmas_per_step = b_tile * k_slab
        # double-buffered slabs: only the warm-up slab of each output
        # tile is an exposed wait; the rest prefetch behind the FMAs
        exposed_waits = (b_pad // b_tile) * d_passes
    total = feat_bytes + idx_bytes + w_bytes + out_bytes
    return {
        "grid_steps": grid_steps,
        "dmas_per_step": dmas_per_step,
        "exposed_waits": exposed_waits,
        "feat_bytes": feat_bytes,
        "w_bytes": w_bytes,
        "bytes_moved": total,
    }


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []
    cases = [(4096, 128, 256, 15), (16384, 256, 512, 10)]
    if quick:
        cases = [(1024, 128, 64, 15)]
    for n, d, b, k in cases:
        feats = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, (b, k)), jnp.int32)
        w = jnp.asarray(rng.random((b, k)) * (rng.random((b, k)) > 0.3),
                        jnp.float32)
        ref = neighbor_agg(feats, idx, w, use_kernel=False)
        ref.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            neighbor_agg(feats, idx, w, use_kernel=False).block_until_ready()
        t_ref = (time.perf_counter() - t0) / 3
        for kernel in ("row", "tiled"):
            ker = neighbor_agg(feats, idx, w, use_kernel=True,
                               kernel=kernel, interpret=True,
                               b_tile=B_TILE, d_tile=D_TILE, k_slab=K_SLAB)
            err = float(jnp.max(jnp.abs(ref - ker)))
            flops = 2.0 * b * k * d
            acct = _accounting(kernel, n, d, b, k)
            rows.append({
                "kernel": kernel, "n": n, "d": d, "b": b, "k": k,
                "jnp_us_per_call": round(t_ref * 1e6, 1),
                "kernel_max_err": err,
                "flops": int(flops),
                "vmem_bytes": _VMEM_BYTES[kernel],
                **acct,
                "arithmetic_intensity": round(flops / acct["bytes_moved"],
                                              3),
                "v5e_hbm_bound_us": round(
                    acct["bytes_moved"] / 819e9 * 1e6, 3),
            })
    write_csv("kernel_microbench", rows)
    print_rows("kernel", rows)
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
