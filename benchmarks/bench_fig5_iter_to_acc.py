"""Fig. 5 / §5.1: iteration-to-accuracy vs time-to-accuracy across batch
and fan-out sizes (reddit-like preset) — the paper's hardware-agnostic
metric argument."""
from __future__ import annotations

from benchmarks.common import gnn_cfg, print_rows, run_minibatch, \
    summarize, write_csv
from repro.data import make_preset


def run(quick: bool = True, seed: int = 0):
    graph = make_preset("reddit-like", seed=seed, n=1600 if quick else 4000,
                        homophily=0.6, feat_scale=0.35, train_frac=0.3)
    iters = 150 if quick else 400
    target_acc = 0.72
    rows = []
    for loss in ("ce", "mse"):
        cfg = gnn_cfg(graph, n_layers=1, loss=loss)
        for b in [32, 128, 512]:
            res, _ = run_minibatch(graph, cfg, b, (10,), iters, seed=seed,
                                   eval_every=1)
            rows.append({"loss": loss, "sweep": "batch", "b": b, "beta": 10,
                         **summarize(res, target_acc=target_acc)})
        for beta in [2, 5, 15]:
            res, _ = run_minibatch(graph, cfg, 128, (beta,), iters,
                                   seed=seed, eval_every=1)
            rows.append({"loss": loss, "sweep": "fanout", "b": 128,
                         "beta": beta,
                         **summarize(res, target_acc=target_acc)})
    write_csv("fig5_iter_to_acc", rows)
    print_rows("fig5", rows)
    return rows


if __name__ == "__main__":
    run()
