"""Remark 3.2: |dT/dβ| slope magnitudes — closed-form bound slopes vs the
empirical iteration-to-loss differences from the Fig.-2 sweep."""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.core import theory as T


def run(quick: bool = True, seed: int = 0):
    rows = []
    n, h = 2000, 16
    for loss, slope in (("mse", T.slope_mse), ("ce", T.slope_ce)):
        for b in (32, 128, 512):
            for beta in (2, 5, 10, 20):
                rows.append({"loss": loss, "b": b, "beta": beta,
                             "abs_dT_dbeta": f"{slope(b, beta):.4g}"})
    # bound values themselves (normalized so trends are inspectable)
    t0 = T.t_mse_minibatch(n, h, 128, 10)
    for b in (32, 128, 512):
        rows.append({"loss": "mse_T", "b": b, "beta": 10,
                     "abs_dT_dbeta":
                     f"{T.t_mse_minibatch(n, h, b, 10) / t0:.4g}"})
    t1 = T.t_ce_minibatch(n, 128, 10)
    for b in (32, 128, 512):
        rows.append({"loss": "ce_T", "b": b, "beta": 10,
                     "abs_dT_dbeta":
                     f"{T.t_ce_minibatch(n, b, 10) / t1:.4g}"})
    write_csv("theory_slopes", rows)
    print_rows("slopes", rows)
    return rows


if __name__ == "__main__":
    run()
