"""Fig. 4: multi-layer (2-layer) GraphSAGE iteration-to-loss across batch
and fan-out sizes, CE and MSE — confirms the one-layer theory trends
survive depth (with the paper's noted fluctuations)."""
from __future__ import annotations

from benchmarks.common import gnn_cfg, print_rows, run_fullgraph, \
    run_minibatch, summarize, write_csv
from repro.data import make_preset


def run(quick: bool = True, seed: int = 0):
    graph = make_preset("arxiv-like", seed=seed, n=1500 if quick else 3000)
    iters = 150 if quick else 400
    rows = []
    target = {"ce": 0.6, "mse": 0.45}
    for loss in ("ce", "mse"):
        cfg = gnn_cfg(graph, n_layers=2, loss=loss, fanout=(10, 5))
        for b in [32, 128, len(graph.train_nodes)]:
            res, _ = run_minibatch(graph, cfg, b, (10, 5), iters, seed=seed)
            rows.append({"loss": loss, "sweep": "batch", "b": b,
                         "beta": "10/5",
                         **summarize(res, target_loss=target[loss])})
        for beta in [2, 5, 10]:
            res, _ = run_minibatch(graph, cfg, 128, (beta, beta), iters,
                                   seed=seed)
            rows.append({"loss": loss, "sweep": "fanout", "b": 128,
                         "beta": beta,
                         **summarize(res, target_loss=target[loss])})
        # full-graph = the (b=n_train, beta=d_max) corner
        res, _ = run_fullgraph(graph, cfg, iters, seed=seed)
        rows.append({"loss": loss, "sweep": "fullgraph",
                     "b": len(graph.train_nodes), "beta": graph.d_max,
                     **summarize(res, target_loss=target[loss])})
    write_csv("fig4_multilayer", rows)
    print_rows("fig4", rows)
    return rows


if __name__ == "__main__":
    run()
