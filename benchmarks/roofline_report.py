"""Deliverable g: aggregate experiments/dryrun/*.json into the §Roofline
table — per (arch x shape x mesh): three terms, dominant bound,
MODEL_FLOPS/HLO ratio, memory fit."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import print_rows, write_csv

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str = None) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def run(quick: bool = True, mesh: str = "16x16"):
    """Roofline terms per (arch x shape).  The compute term is reported
    BOTH ways: raw HLO_FLOPs (as per spec — but XLA counts while-loop
    bodies once, so scanned layers under-report) and the analytic model
    of what this implementation computes (the corrected term used for
    bottleneck identification)."""
    from repro.configs.base import INPUT_SHAPES, get_config
    from repro.launch.roofline import PEAK_FLOPS, analytic_flops, roofline

    rows = []
    for r in load_records(mesh):
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": r["status"],
                         "note": r.get("reason", r.get("error", ""))[:90]})
            continue
        rl = r["roofline"]
        coll = r["collective_bytes_per_device"]
        chips = r.get("chips", 256)
        row = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_hlo_s": f"{rl['compute_s']:.4g}",
            "memory_s": f"{rl['memory_s']:.4g}",
            "collective_s": f"{rl['collective_s']:.4g}",
        }
        cfg = get_config(r["arch"])
        if cfg.family != "gnn" and r["shape"] in INPUT_SHAPES:
            af = analytic_flops(cfg, INPUT_SHAPES[r["shape"]])
            corr = roofline(af / chips, r["per_device_bytes"],
                            coll["total"])
            row["compute_analytic_s"] = f"{corr['compute_s']:.4g}"
            row["dominant"] = corr["dominant"]
            row["compute_fraction"] = f"{corr['compute_fraction']:.3f}"
            mf = r.get("model_flops_global", 0.0)
            row["model_vs_analytic"] = f"{mf / af:.3f}" if af else ""
        else:
            row["compute_analytic_s"] = ""
            row["dominant"] = rl["dominant"]
            row["compute_fraction"] = f"{rl['compute_fraction']:.3f}"
            row["model_vs_analytic"] = ""
        row.update({
            "mem_raw_gib": f"{r['device_bytes_total'] / 2**30:.1f}",
            "mem_tpu_est_gib":
            f"{r.get('device_bytes_tpu_estimate', 0) / 2**30:.1f}",
            "fits_tpu_est": r.get("fits_hbm_tpu_estimate", ""),
            "ag_mb": f"{coll.get('all-gather', 0)/1e6:.0f}",
            "ar_mb": f"{coll.get('all-reduce', 0)/1e6:.0f}",
            "a2a_mb": f"{coll.get('all-to-all', 0)/1e6:.0f}",
        })
        rows.append(row)
    write_csv(f"roofline_{mesh.replace('x','_')}", rows)
    print_rows("roofline", rows)
    return rows


if __name__ == "__main__":
    run()
