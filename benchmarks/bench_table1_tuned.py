"""Table 1: best test accuracy of full-graph vs TUNED mini-batch (grid
search over b and β) for multi-layer GraphSAGE on the four presets."""
from __future__ import annotations

from benchmarks.common import gnn_cfg, print_rows, run_fullgraph, \
    run_minibatch, write_csv
from repro.data import PRESETS, make_preset


def run(quick: bool = True, seed: int = 0):
    rows = []
    iters = 120 if quick else 400
    presets = list(PRESETS)
    for preset in presets:
        graph = make_preset(preset, seed=seed, n=1200 if quick else 3000,
                            homophily=0.55, feat_scale=0.3,
                            train_frac=0.3)
        cfg = gnn_cfg(graph, n_layers=2, loss="ce", fanout=(10, 5))
        rf, _ = run_fullgraph(graph, cfg, iters, seed=seed)
        best = {"acc": -1.0}
        grid_b = [64, 256] if quick else [64, 128, 256, 512]
        grid_beta = [(5, 3), (10, 5)] if quick else \
            [(5, 3), (10, 5), (15, 10), (20, 10)]
        for b in grid_b:
            for fo in grid_beta:
                rm, _ = run_minibatch(graph, cfg, b, fo, iters, seed=seed)
                if rm.final_test_acc > best["acc"]:
                    best = {"acc": rm.final_test_acc, "b": b, "fanout": fo}
        rows.append({
            "preset": preset,
            "full_graph_acc": round(rf.final_test_acc, 4),
            "mini_batch_best_acc": round(best["acc"], 4),
            "best_b": best["b"],
            "best_fanout": str(best["fanout"]),
            "mini_minus_full": round(best["acc"] - rf.final_test_acc, 4),
        })
    write_csv("table1_tuned", rows)
    print_rows("table1", rows)
    return rows


if __name__ == "__main__":
    run()
