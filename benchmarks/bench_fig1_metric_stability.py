"""Fig. 1 / §5.1: hardware-(in)dependence of the metrics.

Real heterogeneous hardware isn't available (hardware gate, DESIGN.md), so
this reproduces the paper's own non-rigorous §5.1 derivation: the SAME
iteration-to-accuracy measurements are combined with different
bandwidth/compute models; the time-to-accuracy RANKING of full-graph vs
mini-batch flips across bandwidths while iteration-to-accuracy is
bandwidth-invariant by construction — plus the real measured CPU variation.
"""
from __future__ import annotations

from benchmarks.common import gnn_cfg, print_rows, run_fullgraph, \
    run_minibatch, summarize, write_csv
from repro.core.metrics import iteration_to_accuracy, simulated_time_to_acc
from repro.data import make_preset


def run(quick: bool = True, seed: int = 0):
    graph = make_preset("arxiv-like", seed=seed, n=1500 if quick else 3000,
                        homophily=0.55, feat_scale=0.3, train_frac=0.3)
    iters = 150 if quick else 400
    target = 0.7
    cfg = gnn_cfg(graph, n_layers=1, loss="ce")
    rf, _ = run_fullgraph(graph, cfg, iters, seed=seed, eval_every=1)
    rm, _ = run_minibatch(graph, cfg, 128, (10,), iters, seed=seed,
                          eval_every=1)
    it_full = iteration_to_accuracy(rf.history, target) or iters
    it_mini = iteration_to_accuracy(rm.history, target) or iters
    nodes_full = len(graph.train_nodes) * graph.avg_degree
    nodes_mini = 128 * 10
    rows = []
    for bw_name, bw in [("bw_high(1e6)", 1e6), ("bw_mid(1e4)", 1e4),
                        ("bw_low(1e2)", 1e2)]:
        t_full = simulated_time_to_acc(it_full, nodes_full, bw)
        t_mini = simulated_time_to_acc(it_mini, nodes_mini, bw)
        rows.append({
            "bandwidth": bw_name,
            "iter_to_acc_full": it_full, "iter_to_acc_mini": it_mini,
            "time_to_acc_full_s": round(t_full, 4),
            "time_to_acc_mini_s": round(t_mini, 4),
            "faster_paradigm": "full" if t_full < t_mini else "mini",
        })
    # iteration-to-acc is identical across rows by construction; the
    # winner by time flips -> the paper's point.
    write_csv("fig1_metric_stability", rows)
    print_rows("fig1", rows)
    return rows


if __name__ == "__main__":
    run()
