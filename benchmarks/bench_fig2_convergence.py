"""Fig. 2 / Thm 1-2: iteration-to-loss of one-layer GraphSAGE under CE and
MSE across batch sizes and fan-out sizes (products-like regime).

Methodology matches the paper's "across varying learning rates": the
theory's T(b, β) holds for lr tuned within a (b, β)-dependent stability
range (App. B-E set η ∈ [C β³/(π n b²), b/(6π β n)]), so each sweep point
reports the BEST iteration-to-loss over an lr grid, seed-averaged, with
the loss measured on the FULL training objective (per-batch losses are
noisy and their first crossings bias small batches early).

Validates Remark 3.1:
  * MSE: larger b -> MORE iterations; larger β -> fewer.
  * CE:  larger b -> fewer iterations; larger β -> fewer.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import gnn_cfg, print_rows, write_csv
from repro.core.metrics import iteration_to_full_loss
from repro.core.trainer import train_minibatch
from repro.data import make_preset

LR_GRID = {
    "ce": (0.02, 0.06, 0.2, 0.6),
    "mse": (0.004, 0.012, 0.04, 0.12),
}


def _one(graph, cfg, b, fanouts, iters, lr, seed):
    return train_minibatch(graph, cfg, lr=lr, n_iters=iters, batch_size=b,
                           fanouts=fanouts, seed=seed, eval_every=10 ** 9,
                           track_full_loss_every=5)


def _best_over_lr(graph, cfg, b, fanouts, iters, target, seeds):
    best_it, best_lr, best_final = iters * 2, None, float("inf")
    for lr in LR_GRID[cfg.loss]:
        its, finals = [], []
        for s in seeds:
            r = _one(graph, cfg, b, fanouts, iters, lr, s)
            fl = r.history.full_losses
            if not np.isfinite(fl[-1]):           # diverged
                its.append(iters * 2)
                finals.append(float("inf"))
                continue
            it = iteration_to_full_loss(r.history, target)
            its.append(it if it is not None else iters * 2)
            finals.append(fl[-1])
        m = float(np.mean(its))
        if m < best_it:
            best_it, best_lr, best_final = m, lr, float(np.mean(finals))
    return best_it, best_lr, best_final


def run(quick: bool = True, seed: int = 0):
    graph = make_preset("products-like", seed=seed,
                        n=1600 if quick else 4000,
                        homophily=0.6, feat_scale=0.45)
    iters = 250 if quick else 600
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    rows = []
    batches = [32, 128, 512, len(graph.train_nodes)]
    fanouts = [2, 5, 10, min(20, graph.d_max)]
    for loss in ("ce", "mse"):
        cfg = gnn_cfg(graph, n_layers=1, loss=loss)
        # target: what the reference config (b=128, β=10) reaches at 60%
        # budget under ITS best lr
        ref_best = float("inf")
        for lr in LR_GRID[loss]:
            r = _one(graph, cfg, 128, (10,), iters, lr, 99)
            fl = [x for x in r.history.full_losses if np.isfinite(x)]
            if fl and fl[int(len(fl) * 0.6)] < ref_best:
                ref_best = fl[int(len(fl) * 0.6)]
        target = ref_best
        for b in batches:
            it, lr, flv = _best_over_lr(graph, cfg, b, (10,), iters,
                                        target, seeds)
            rows.append({"sweep": "batch", "loss": loss, "b": b, "beta": 10,
                         "target": round(target, 4),
                         "iter_to_loss": round(it, 1), "best_lr": lr,
                         "final_loss": round(flv, 4)})
        for beta in fanouts:
            it, lr, flv = _best_over_lr(graph, cfg, 128, (beta,), iters,
                                        target, seeds)
            rows.append({"sweep": "fanout", "loss": loss, "b": 128,
                         "beta": beta, "target": round(target, 4),
                         "iter_to_loss": round(it, 1), "best_lr": lr,
                         "final_loss": round(flv, 4)})
    write_csv("fig2_convergence", rows)
    print_rows("fig2", rows)
    return rows


if __name__ == "__main__":
    run()
