"""Thm 3 / Def. 1: Δ(β, b) Wasserstein curves and per-node
δ_i^{full-mini}(β) — the generalization-analysis quantities."""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.core.wasserstein import delta_full_mini, wasserstein_delta
from repro.data import make_preset


def run(quick: bool = True, seed: int = 0):
    graph = make_preset("arxiv-like", seed=seed, n=1200 if quick else 3000)
    rows = []
    betas = [1, 2, 5, 10, 15, graph.d_max]
    for beta in betas:
        w = wasserstein_delta(graph, beta=beta, b=128)
        rows.append({"sweep": "fanout", "beta": beta, "b": 128,
                     "delta": round(w["delta"], 6),
                     "delta_full_mini_mean":
                     round(w["delta_full_mini_mean"], 6)})
    n_tr = len(graph.train_nodes)
    for b in [32, 128, 512, n_tr]:
        w = wasserstein_delta(graph, beta=5, b=b)
        rows.append({"sweep": "batch", "beta": 5, "b": b,
                     "delta": round(w["delta"], 6),
                     "delta_full_mini_mean":
                     round(w["delta_full_mini_mean"], 6)})
    write_csv("thm3_wasserstein", rows)
    print_rows("thm3", rows)
    return rows


if __name__ == "__main__":
    run()
