"""LM pretraining example: reduced-config training via the production
launcher (AdamW, remat, checkpointing).  Any of the 10 assigned archs:

    PYTHONPATH=src python examples/lm_pretrain_smoke.py --arch zamba2-7b
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()
    sys.argv = ["train", "--arch", args.arch, "--smoke",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128"]
    train_main()


if __name__ == "__main__":
    main()
