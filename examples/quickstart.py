"""Quickstart: train a GraphSAGE model with the paper's two paradigms on a
synthetic ogbn-arxiv-like graph and compare them — both run through the
SAME engine (`repro.core.engine.Trainer`); only the BatchSource differs.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import GNNConfig
from repro.core.engine import (FullGraphSource, SampledSource, Trainer,
                               TrainPlan)
from repro.core.metrics import iteration_to_loss
from repro.data import make_preset


def main():
    graph = make_preset("arxiv-like", n=1500, seed=0)
    print(f"graph: n={graph.n} avg_deg={graph.avg_degree:.1f} "
          f"d_max={graph.d_max} classes={graph.n_classes}")

    cfg = GNNConfig(name="quickstart", model="graphsage",
                    n_nodes=graph.n, feat_dim=graph.feats.shape[1],
                    hidden=64, n_classes=graph.n_classes, n_layers=2,
                    fanout=(10, 5), batch_size=256, loss="ce")
    plan = TrainPlan(lr=0.3, n_iters=100)

    # full-graph GD is the (b=n_train, beta=d_max) limit of mini-batch:
    # same Trainer, different BatchSource.
    full = Trainer(graph, cfg, plan, source=FullGraphSource()).run()
    mini = Trainer(graph, cfg, plan, source=SampledSource()).run()

    for name, res in [("full-graph", full), ("mini-batch", mini)]:
        itl = iteration_to_loss(res.history, 0.5)
        print(f"{name:11s} loss {res.history.losses[0]:.3f} -> "
              f"{res.history.losses[-1]:.3f}  "
              f"iter-to-loss(0.5)={itl}  test acc {res.final_test_acc:.3f}")
    print("\nPaper's takeaway: tune (b, beta) before assuming full-graph "
          "wins — see repro.core.experiment.sweep and benchmarks/ for "
          "the full grids.")


if __name__ == "__main__":
    main()
