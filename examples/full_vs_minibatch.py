"""End-to-end driver (deliverable b): a few hundred training steps of the
paper's two paradigms at the largest CPU-tractable preset, with the full
metric suite — iteration-to-loss/accuracy, time-to-accuracy, throughput —
and the Theorem-3 Wasserstein diagnostic for the chosen (b, beta).

Runs entirely through the unified engine: `run_experiment` drives one
`Trainer` per paradigm; `--sweep` additionally runs a small (b, β) grid
through `repro.core.experiment.sweep` and writes JSON/CSV rows.

    PYTHONPATH=src python examples/full_vs_minibatch.py \
        --preset products-like --iters 300 --b 256 --beta 10 5
    PYTHONPATH=src python examples/full_vs_minibatch.py --sweep
"""
import argparse
import json

from repro.configs.base import GNNConfig
from repro.core.engine import TrainPlan
from repro.core.experiment import run_experiment, save_rows, sweep
from repro.core.wasserstein import wasserstein_delta
from repro.data import make_preset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="products-like")
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--b", type=int, default=256)
    ap.add_argument("--beta", type=int, nargs="+", default=[10, 5])
    ap.add_argument("--loss", default="ce", choices=["ce", "mse"])
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--sweep", action="store_true",
                    help="also run a small (b, β) grid and write JSON/CSV")
    args = ap.parse_args()

    graph = make_preset(args.preset, n=args.n, seed=0)
    cfg = GNNConfig(name="e2e", model="graphsage", n_nodes=graph.n,
                    feat_dim=graph.feats.shape[1], hidden=64,
                    n_classes=graph.n_classes, n_layers=len(args.beta),
                    fanout=tuple(args.beta), batch_size=args.b,
                    loss=args.loss)
    plan = TrainPlan(lr=args.lr, n_iters=args.iters, eval_every=5)

    # report iteration-to-* against the paper's targets without stopping
    # early — the runs go the full --iters like the original driver
    report = dict(report_loss=0.5, report_acc=0.6)
    print(f"== full-graph GD ({args.iters} iters, b=n_train="
          f"{len(graph.train_nodes)}, beta=d_max={graph.d_max})")
    row_full = run_experiment(graph, cfg, plan, paradigm="fullgraph",
                              **report)
    print(f"== mini-batch SGD (b={args.b}, beta={tuple(args.beta)})")
    row_mini = run_experiment(graph, cfg, plan, paradigm="minibatch",
                              b=args.b, fanouts=tuple(args.beta),
                              **report)

    report = {"full_graph": row_full, "mini_batch": row_mini}
    w = wasserstein_delta(graph, beta=args.beta[0], b=args.b)
    report["thm3_delta(beta,b)"] = round(w["delta"], 6)
    report["delta_full_mini_mean"] = round(w["delta_full_mini_mean"], 6)
    print(json.dumps(report, indent=2))

    if args.sweep:
        grid_bs = sorted({max(args.b // 4, 8), args.b})
        grid_fo = [tuple(max(f // 2, 1) for f in args.beta),
                   tuple(args.beta)]
        # grid runs use the engine's early stop: each point trains until
        # the target loss (the paper's iteration-to-loss protocol)
        plan = TrainPlan(lr=args.lr, n_iters=args.iters, eval_every=5,
                         target_loss=0.5)
        rows = sweep(graph, cfg, plan, batch_sizes=grid_bs,
                     fanout_grid=grid_fo, include_fullgraph=True,
                     verbose=True)
        paths = save_rows("full_vs_minibatch_sweep", rows)
        print(json.dumps({"sweep_rows": len(rows), **paths}))


if __name__ == "__main__":
    main()
