"""End-to-end driver (deliverable b): a few hundred training steps of the
paper's two paradigms at the largest CPU-tractable preset, with the full
metric suite — iteration-to-loss/accuracy, time-to-accuracy, throughput —
and the Theorem-3 Wasserstein diagnostic for the chosen (b, beta).

    PYTHONPATH=src python examples/full_vs_minibatch.py \
        --preset products-like --iters 300 --b 256 --beta 10 5
"""
import argparse
import json

from repro.configs.base import GNNConfig
from repro.core.metrics import (iteration_to_accuracy, iteration_to_loss,
                                throughput_nodes_per_sec, time_to_accuracy)
from repro.core.trainer import train_full_graph, train_minibatch
from repro.core.wasserstein import wasserstein_delta
from repro.data import make_preset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="products-like")
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--b", type=int, default=256)
    ap.add_argument("--beta", type=int, nargs="+", default=[10, 5])
    ap.add_argument("--loss", default="ce", choices=["ce", "mse"])
    ap.add_argument("--lr", type=float, default=0.3)
    args = ap.parse_args()

    graph = make_preset(args.preset, n=args.n, seed=0)
    cfg = GNNConfig(name="e2e", model="graphsage", n_nodes=graph.n,
                    feat_dim=graph.feats.shape[1], hidden=64,
                    n_classes=graph.n_classes, n_layers=len(args.beta),
                    fanout=tuple(args.beta), batch_size=args.b,
                    loss=args.loss)

    print(f"== full-graph GD ({args.iters} iters, b=n_train="
          f"{len(graph.train_nodes)}, beta=d_max={graph.d_max})")
    rf = train_full_graph(graph, cfg, lr=args.lr, n_iters=args.iters,
                          eval_every=5)
    print(f"== mini-batch SGD (b={args.b}, beta={tuple(args.beta)})")
    rm = train_minibatch(graph, cfg, lr=args.lr, n_iters=args.iters,
                         eval_every=5)

    target_loss, target_acc = 0.5, 0.6
    report = {}
    for name, r in [("full_graph", rf), ("mini_batch", rm)]:
        report[name] = {
            "final_loss": round(r.history.losses[-1], 4),
            "test_acc": round(r.final_test_acc, 4),
            "iter_to_loss@0.5": iteration_to_loss(r.history, target_loss),
            "iter_to_acc@0.6": iteration_to_accuracy(r.history, target_acc),
            "time_to_acc@0.6_s": time_to_accuracy(r.history, target_acc),
            "throughput_nodes_s":
            round(throughput_nodes_per_sec(r.history), 1),
        }
    w = wasserstein_delta(graph, beta=args.beta[0], b=args.b)
    report["thm3_delta(beta,b)"] = round(w["delta"], 6)
    report["delta_full_mini_mean"] = round(w["delta_full_mini_mean"], 6)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
