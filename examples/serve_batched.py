"""Batched serving example: prefill a batch of prompts on a reduced
stablelm config and decode with sampled continuation — exercises the
prefill/decode_step public API + KV ring caches.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-12b
(uses the reduced same-family config; pass --gen/--batch to scale)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.frontend_seq:
        batch["patches"] = jnp.zeros(
            (args.batch, cfg.frontend_seq, cfg.d_model), jnp.float32)
    if cfg.n_enc_layers:
        batch["frames"] = jnp.zeros(
            (args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, b: M.prefill(
        p, cfg, b, max_len=args.prompt_len + args.gen))
    decode = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill: {time.perf_counter() - t0:.2f}s "
          f"(batch={args.batch}, prompt={args.prompt_len})")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = []
    key = jax.random.key(1)
    t0 = time.perf_counter()
    for _ in range(args.gen):
        outs.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok)
        key, sk = jax.random.split(key)
        tok = jax.random.categorical(sk, logits)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"decode: {args.gen} steps, "
          f"{args.batch * args.gen / dt:.1f} tok/s (batched)")
    print("sample:", np.stack(outs, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
