"""CI smoke for crash-safe sweeps (scripts/ci.sh, `make chaos`).

Simulates the real failure mode end to end: a sweep over two grid
points is killed right after the first point finishes (armed
``sweep.after_point`` failpoint -> SimulatedCrash), then rerun with the
same journal.  The resumed sweep must (a) NOT rerun the completed
point — its row comes back from the journal — and (b) finish the grid,
leaving exactly one journal line per point.

    PYTHONPATH=src python scripts/sweep_resume_smoke.py
"""
import json
import os
import sys
import tempfile

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.configs.base import GNNConfig                    # noqa: E402
from repro.core import faults                               # noqa: E402
from repro.core.engine import TrainPlan                     # noqa: E402
from repro.core.experiment import sweep                     # noqa: E402
from repro.data import make_preset                          # noqa: E402


def main() -> int:
    graph = make_preset("arxiv-like", n=200, seed=0)
    cfg = GNNConfig(name="smoke", model="graphsage", n_nodes=graph.n,
                    feat_dim=graph.feats.shape[1], hidden=16,
                    n_classes=graph.n_classes, n_layers=1, fanout=(3,),
                    batch_size=32, loss="ce")
    plan = TrainPlan(lr=0.3, n_iters=3, eval_every=2)
    kw = dict(batch_sizes=[16, 32], fanout_grid=[(3,)], verbose=True)

    with tempfile.TemporaryDirectory() as d:
        journal = os.path.join(d, "sweep.jsonl")

        # -- run 1: killed right after point 1 is journaled ------------
        crashed = False
        try:
            with faults.armed("sweep.after_point", at_hits=(0,)):
                sweep(graph, cfg, plan, journal=journal, **kw)
        except faults.SimulatedCrash:
            crashed = True
        assert crashed, "failpoint sweep.after_point did not fire"
        lines = [json.loads(l) for l in open(journal)]
        assert len(lines) == 1 and lines[0]["status"] == "ok", lines
        first_row = lines[0]["row"]

        # -- run 2: same journal — resume must skip point 1 ------------
        rows = sweep(graph, cfg, plan, journal=journal, **kw)
        lines = [json.loads(l) for l in open(journal)]
        assert len(rows) == 2, rows
        # one journal line per point: point 1 was NOT rerun
        assert len(lines) == 2, lines
        assert [l["status"] for l in lines] == ["ok", "ok"]
        # the skipped point's row is the journaled one, verbatim
        assert rows[0] == first_row, (rows[0], first_row)

    print("sweep_resume_smoke: OK (point 1 journaled once, "
          "resume skipped it, grid completed)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
