#!/usr/bin/env bash
# Per-PR check: tier-1 tests + quick perf benches so sampler/kernel
# regressions are visible in the PR log.  Run from the repo root
# (or via `make check`).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== static audit (jaxpr / pallas / thread checkers + the seeded =="
echo "== broken-fixture self-test; traced jaxprs cached by src digest) =="
make analyze
make analyze-fixtures

echo "== kernel micro-bench (quick) =="
python benchmarks/bench_kernel.py --quick

echo "== sampler micro-bench (quick) =="
python benchmarks/bench_sampler.py --quick

# the gate compares absolute steps/s against the committed
# BENCH_engine.json (recorded on the authoring machine) — on a much
# slower or loaded host, widen the tolerance, e.g. BENCH_TOL=0.6, and
# refresh the baseline from the canonical machine via
# `make bench-engine-baseline`
echo "== engine throughput bench (smoke + regression gate, incl. the =="
echo "== 4-virtual-device sharded rows, keyed @4dev in the baseline) =="
python benchmarks/bench_engine.py --smoke --check --devices 4

echo "== experiment sweep smoke (2 minibatch grid points + one point =="
echo "== per scenario source: cluster / importance / minibatch_sharded, =="
echo "== plus one sharded x Pallas-kernel point and one 4-virtual- =="
echo "== device feats_layout=sharded (featshard) point, interpret mode) =="
make sweep-smoke

echo "== serving smoke (layer-wise embedding build == naive forward, =="
echo "== micro-batched queries, incremental refresh; einsum + kernel) =="
make serve-smoke

echo "== chaos suite (fault injection: worker death, NaN steps, =="
echo "== kill-mid-checkpoint, sweep journal kill/resume) =="
make chaos
