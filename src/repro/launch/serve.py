"""Serving driver — family-dispatched.

GNN configs serve batched node-classification queries from cached
layer-wise embeddings (core.inference -> core.embedding_store ->
core.serving):

    PYTHONPATH=src python -m repro.launch.serve --smoke

The smoke path builds a tiny synthetic graph, runs the layer-wise
embedding pass, CHECKS it per-layer against the naive full-graph
forward, answers N micro-batched queries (from concurrent client
threads), verifies every answer against the direct forward argmax,
then mutates a few node features and re-serves through the incremental
re-embed path — exercising the whole tier end to end.  A write-load
phase follows (PR 10): a writer thread streams feature updates through
the WAL while concurrent clients query, with one injected
mid-refresh crash (``store.mid_layer_refresh``) killing the background
refresh scheduler — answers must keep coming from the last consistent
snapshot; then a tight ``max_staleness_s`` SLO forces a synchronous
refresh and the served answers must match the fully updated forward.
Exit is nonzero on any mismatch.

Decoder families keep the prefill/decode-step driver:

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config


# ---------------------------------------------------------------------------
# GNN: layer-wise embed + batched query serving
# ---------------------------------------------------------------------------

def serve_gnn(args, cfg) -> int:
    from repro.core import gnn as G
    from repro.core.embedding_store import EmbeddingStore
    from repro.core.serving import GNNServer
    from repro.data.synth import make_preset

    if not args.smoke:
        raise SystemExit(
            "gnn serving currently has only the synthetic --smoke path "
            "(real-dataset serving is ROADMAP work); re-run with --smoke")

    graph = make_preset(args.preset, n=args.nodes, seed=args.seed)
    cfg = dataclasses.replace(
        cfg, n_nodes=graph.n, feat_dim=graph.feats.shape[1],
        n_classes=graph.n_classes, use_agg_kernel=args.kernel,
        agg_interpret=True)
    params = G.init_gnn(jax.random.key(args.seed), cfg,
                        graph.feats.shape[1])

    store = EmbeddingStore(params, cfg, graph, chunk_size=args.chunk)
    run = store.build()

    # layer-wise output must equal the naive full-graph forward
    naive_logits, naive_layers = G.full_graph_forward(
        params, cfg, jnp.asarray(graph.feats), jnp.asarray(store.idx),
        jnp.asarray(store.w), jnp.asarray(store.w_self),
        return_layers=True)
    layers_ok = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
        for a, b in zip(run.layers, naive_layers))
    expect = np.argmax(np.asarray(naive_logits), -1)

    # batched queries from concurrent clients through the micro-batcher
    rng = np.random.default_rng(args.seed + 1)
    queries = [rng.integers(0, graph.n, size=rng.integers(1, 9))
               for _ in range(args.queries)]
    server = GNNServer(store, max_batch=args.max_batch,
                       max_wait_ms=args.max_wait_ms)
    try:
        with ThreadPoolExecutor(max_workers=args.concurrency) as pool:
            answers = list(pool.map(
                lambda q: server.classify(q, timeout=60.0), queries))
    finally:
        server.close()
    st = server.stats()
    serve_ok = all(np.array_equal(a, expect[q])
                   for a, q in zip(answers, queries))
    counters_ok = (st["n_queries"] == sum(len(q) for q in queries)
                   and st["n_batches"] >= 1 and st["p99_ms"] > 0.0
                   and st["p99_ms"] >= st["p50_ms"])

    # incremental path: perturb features, re-serve, re-verify
    upd = rng.choice(graph.n, size=args.updates, replace=False)
    store.update_features(
        upd, rng.normal(size=(args.updates, graph.feats.shape[1]))
        .astype(np.float32))
    refresh = store.refresh()
    post_logits = G.full_graph_forward(
        params, cfg, jnp.asarray(graph.feats), jnp.asarray(store.idx),
        jnp.asarray(store.w), jnp.asarray(store.w_self))
    post_expect = np.argmax(np.asarray(post_logits), -1)
    check = rng.integers(0, graph.n, size=64)
    update_ok = np.array_equal(store.predict(check), post_expect[check])
    incremental = 0 < refresh["total_rows"] < graph.n * cfg.n_layers

    # ---- write-load phase A: concurrent writer + queries + one
    # injected mid-refresh crash.  The scheduler thread dies on its
    # first re-embed attempt, so NO new version can be published —
    # every concurrent answer must come from the last consistent
    # snapshot (the pre-phase state), byte-for-byte.
    import threading

    from repro.core import faults

    v0 = store.version
    old_hook = threading.excepthook
    threading.excepthook = lambda a: None     # the injected crash is loud
    wserver = GNNServer(store, max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms,
                        max_staleness_s=30.0,      # loose: scheduler owns
                        refresh_every_updates=4)   # the refresh cadence
    try:
        faults.arm("store.mid_layer_refresh", at_hits=(0,))

        def _writer():
            w_rng = np.random.default_rng(args.seed + 2)
            for _ in range(8):
                nodes = w_rng.choice(graph.n, size=2, replace=False)
                store.update_features(
                    nodes, w_rng.normal(size=(2, graph.feats.shape[1]))
                    .astype(np.float32))
                time.sleep(0.003)

        wt = threading.Thread(target=_writer)
        wt.start()
        wqueries = [rng.integers(0, graph.n, size=8) for _ in range(32)]
        with ThreadPoolExecutor(max_workers=args.concurrency) as pool:
            wanswers = list(pool.map(
                lambda q: wserver.submit(q, with_meta=True)
                .result(timeout=60.0), wqueries))
        wt.join(timeout=60.0)
        sched = store._sched_thread
        if sched is not None:
            sched.join(timeout=30.0)          # killed by the failpoint
    finally:
        faults.disarm()
        wserver.close()
        threading.excepthook = old_hook
    chaos_ok = (store.version == v0 and store.dirty
                and all(a.snapshot_version == v0
                        and np.array_equal(a.preds, post_expect[q])
                        for a, q in zip(wanswers, wqueries)))

    # recovery: a manual refresh catches up on everything the crashed
    # scheduler left in the WAL/dirty masks
    store.refresh()
    rec_logits = G.full_graph_forward(
        params, cfg, jnp.asarray(store.graph.feats), jnp.asarray(store.idx),
        jnp.asarray(store.w), jnp.asarray(store.w_self))
    rec_expect = np.argmax(np.asarray(rec_logits), -1)
    recovery_ok = (store.version == v0 + 1 and not store.dirty
                   and np.array_equal(store.predict_meta(check)[0],
                                      rec_expect[check]))

    # ---- write-load phase B: hard staleness SLO — aged updates force
    # a synchronous refresh on the serve path, so the answer is fresh
    slo_server = GNNServer(store, max_batch=args.max_batch,
                           max_wait_ms=args.max_wait_ms,
                           max_staleness_s=0.05)
    try:
        upd2 = rng.choice(graph.n, size=4, replace=False)
        store.update_features(
            upd2, rng.normal(size=(4, graph.feats.shape[1]))
            .astype(np.float32))
        time.sleep(0.1)                       # age past the bound
        ans = slo_server.submit(check, with_meta=True).result(timeout=60.0)
        slo_stats = slo_server.stats()
    finally:
        slo_server.close()
    slo_logits = G.full_graph_forward(
        params, cfg, jnp.asarray(store.graph.feats), jnp.asarray(store.idx),
        jnp.asarray(store.w), jnp.asarray(store.w_self))
    slo_expect = np.argmax(np.asarray(slo_logits), -1)
    slo_ok = (ans.staleness_s <= 0.05
              and ans.snapshot_version == store.version
              and slo_stats["n_forced_refresh"] >= 1
              and np.array_equal(ans.preds, slo_expect[check]))

    ok = (layers_ok and serve_ok and counters_ok and update_ok
          and chaos_ok and recovery_ok and slo_ok)
    print(json.dumps({
        "arch": args.arch, "family": "gnn", "model": cfg.model,
        "n_nodes": graph.n, "n_layers": cfg.n_layers,
        "kernel": bool(cfg.use_agg_kernel),
        "embed_ms_per_node": run.stats["ms_per_node"],
        "n_chunks": run.stats["n_chunks"],
        "layerwise_matches_naive": layers_ok,
        "serve": {k: round(v, 3) if isinstance(v, float) else v
                  for k, v in st.items()},
        "serve_answers_match_forward": serve_ok,
        "counters_populated": counters_ok,
        "update_reembedded_rows": refresh["total_rows"],
        "update_incremental": incremental,
        "post_update_answers_match_forward": update_ok,
        "write_phase": {
            "chaos_answers": len(wanswers),
            "chaos_served_version": int(v0),
            "chaos_old_snapshot_consistent": chaos_ok,
            "recovery_refresh_consistent": recovery_ok,
            "slo_forced_refreshes": int(slo_stats["n_forced_refresh"]),
            "slo_staleness_s": round(float(ans.staleness_s), 4),
            "slo_fresh_and_consistent": slo_ok,
        },
        "ok": ok,
    }, indent=2))
    return 0 if ok else 1


# ---------------------------------------------------------------------------
# decoder families: prefill + decode-step driver
# ---------------------------------------------------------------------------

def serve_decoder(args, cfg) -> int:
    from repro.models import model as M

    if not cfg.has_decode:
        raise SystemExit(
            f"config '{cfg.name}' (family={cfg.family}) has no decode "
            f"step to serve — GNN families go through serve_gnn, "
            f"encoder-only families have no serving driver")
    key = jax.random.key(args.seed)
    params = M.init_model(key, cfg)
    rng = np.random.default_rng(args.seed)

    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.frontend_seq:
        batch["patches"] = jnp.zeros((b, cfg.frontend_seq, cfg.d_model),
                                     M._dt(cfg))
    if cfg.n_enc_layers:
        batch["frames"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                                    M._dt(cfg))

    prefill = jax.jit(lambda p, bb: M.prefill(p, cfg, bb))
    decode = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.gen):
        toks.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_dec = time.perf_counter() - t0

    out = np.stack(toks, 1)
    print(json.dumps({
        "arch": args.arch,
        "prefill_s": round(t_prefill, 4),
        "decode_tok_per_s": round(args.batch * args.gen / t_dec, 2),
        "generated_shape": list(out.shape),
        "sample_tokens": out[0][:16].tolist(),
    }, indent=2))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gnn-papers100m",
                    help="config name (default: the GNN serving smoke)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # decoder knobs
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    # gnn serving knobs
    ap.add_argument("--preset", default="arxiv-like")
    ap.add_argument("--nodes", type=int, default=400,
                    help="synthetic graph size for the gnn smoke")
    ap.add_argument("--chunk", type=int, default=128,
                    help="layer-wise inference chunk size")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--updates", type=int, default=6,
                    help="feature updates for the incremental re-serve")
    ap.add_argument("--kernel", action="store_true",
                    help="route gnn aggregation through the Pallas kernel")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "gnn":
        return serve_gnn(args, cfg)
    return serve_decoder(args, cfg)


if __name__ == "__main__":
    sys.exit(main())
