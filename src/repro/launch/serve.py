"""Batched serving driver: prefill a prompt batch, then step the decoder.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    assert cfg.family != "gnn", "GNNs don't decode; use launch.train"
    key = jax.random.key(args.seed)
    params = M.init_model(key, cfg)
    rng = np.random.default_rng(args.seed)

    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.frontend_seq:
        batch["patches"] = jnp.zeros((b, cfg.frontend_seq, cfg.d_model),
                                     M._dt(cfg))
    if cfg.n_enc_layers:
        batch["frames"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model),
                                    M._dt(cfg))

    prefill = jax.jit(lambda p, bb: M.prefill(p, cfg, bb))
    decode = jax.jit(lambda p, c, t: M.decode_step(p, cfg, c, t))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.gen):
        toks.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(
                sk, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_dec = time.perf_counter() - t0

    out = np.stack(toks, 1)
    print(json.dumps({
        "arch": args.arch,
        "prefill_s": round(t_prefill, 4),
        "decode_tok_per_s": round(args.batch * args.gen / t_dec, 2),
        "generated_shape": list(out.shape),
        "sample_tokens": out[0][:16].tolist(),
    }, indent=2))


if __name__ == "__main__":
    main()
