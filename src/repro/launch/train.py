"""Production-style training driver.

LM archs:  synthetic token pipeline -> jit'd train_step (AdamW, remat,
sharded when a mesh is requested) -> checkpoints + metrics.
GNN arch:  runs the paper's two paradigms on a synthetic preset.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch gnn-papers100m \
        --smoke --steps 200
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as sh
from repro.checkpoint import save_checkpoint
from repro.configs.base import get_config
from repro.data import make_preset, token_batches
from repro.launch.mesh import make_host_mesh


def train_lm(args) -> dict:
    from repro.models import model as M
    from repro.models import steps as S

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model_par=args.model_par)
    key = jax.random.key(args.seed)

    with sh.activate(mesh):
        params = M.init_model(key, cfg)
        specs = M.param_specs(cfg, params)
        params = jax.device_put(params, sh.tree_named(specs, mesh))
        opt, train_step = S.make_train_step(cfg)
        opt_state = opt.init(params)
        step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        losses = []
        t0 = time.perf_counter()
        gen = token_batches(cfg.vocab_size, args.batch, args.seq,
                            seed=args.seed)
        for it in range(args.steps):
            hb = next(gen)
            batch = {"tokens": jnp.asarray(hb["tokens"]),
                     "labels": jnp.asarray(hb["labels"])}
            if cfg.frontend_seq:
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.frontend_seq, cfg.d_model),
                    M._dt(cfg))
            if cfg.n_enc_layers:
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.enc_seq, cfg.d_model), M._dt(cfg))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if it % args.log_every == 0:
                tok_s = (args.batch * args.seq * (it + 1)
                         / (time.perf_counter() - t0))
                print(f"step {it:5d} loss {loss:8.4f} "
                      f"acc {float(metrics['acc']):.3f} tok/s {tok_s:,.0f}",
                      flush=True)
            if args.ckpt_every and it and it % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, it, params,
                                {"arch": args.arch, "loss": loss},
                                keep_last=args.keep_last or None)
    result = {"arch": args.arch, "first_loss": losses[0],
              "final_loss": losses[-1], "steps": len(losses)}
    print(json.dumps(result))
    return result


def train_gnn(args) -> dict:
    """Both paradigms through the unified engine; a --sweep-bs /
    --sweep-fanout grid runs through the experiment runner instead."""
    from repro.core.engine import (FullGraphSource, SampledSource,
                                   Trainer, TrainPlan)
    from repro.core.experiment import save_rows, sweep

    cfg = get_config(args.arch, smoke=args.smoke)
    graph = make_preset(args.preset, seed=args.seed)
    cfg_run = cfg.__class__(**{**cfg.__dict__,
                               "n_classes": graph.n_classes,
                               "feat_dim": graph.feats.shape[1]})
    plan = TrainPlan(lr=args.lr, n_iters=args.steps, seed=args.seed,
                     eval_every=args.log_every,
                     ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                     ckpt_keep_last=args.keep_last)
    if args.sweep_bs or args.sweep_fanout:
        # each --sweep-fanout value is ONE grid point, broadcast to all
        # hops by sweep() (so `--sweep-fanout 5 10 15` sweeps β)
        rows = sweep(graph, cfg_run, plan,
                     batch_sizes=args.sweep_bs or [cfg_run.batch_size],
                     fanout_grid=[int(f) for f in args.sweep_fanout]
                     if args.sweep_fanout else [cfg_run.fanout],
                     include_fullgraph=True, verbose=True,
                     journal=args.journal)
        paths = save_rows(f"{args.arch}_sweep", rows)
        result = {"arch": args.arch, "sweep_rows": len(rows), **paths}
        print(json.dumps(result, indent=2))
        return result
    # the two paradigm Trainers share plan.ckpt_dir: namespace their
    # checkpoints (and any --resume) per paradigm so the manifests don't
    # clobber each other
    def _plan_for(tag):
        return (plan if not (plan.ckpt_every or args.resume) else
                plan.__class__(**{**plan.__dict__,
                                  "ckpt_dir": os.path.join(plan.ckpt_dir,
                                                           tag)}))

    pf, pm = _plan_for("fullgraph"), _plan_for("minibatch")
    rf = Trainer(graph, cfg_run, pf, source=FullGraphSource()).run(
        resume_from=pf.ckpt_dir if args.resume else None)
    rm = Trainer(graph, cfg_run, pm, source=SampledSource()).run(
        resume_from=pm.ckpt_dir if args.resume else None)
    result = {
        "arch": args.arch, "preset": args.preset,
        "full_graph": {"final_loss": rf.history.losses[-1],
                       "test_acc": rf.final_test_acc},
        "mini_batch": {"final_loss": rm.history.losses[-1],
                       "test_acc": rm.final_test_acc},
    }
    print(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preset", default="arxiv-like")
    ap.add_argument("--sweep-bs", type=int, nargs="*", default=None,
                    help="GNN only: batch sizes for a (b, β) sweep")
    ap.add_argument("--sweep-fanout", type=int, nargs="*", default=None,
                    help="GNN only: fan-out grid values; each value is "
                         "one grid point, broadcast to every hop")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="experiments/ckpt")
    ap.add_argument("--keep-last", type=int, default=0,
                    help="checkpoint retention: keep only the newest K "
                         "steps (0 = keep all)")
    ap.add_argument("--resume", action="store_true",
                    help="GNN only: resume each paradigm from the "
                         "latest checkpoint under its --ckpt-dir "
                         "namespace (exact resume — continues the "
                         "interrupted run bit-for-bit)")
    ap.add_argument("--journal", default=None,
                    help="GNN sweeps: JSONL completion journal for "
                         "crash-safe resume (see core.experiment.sweep)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "gnn":
        train_gnn(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
