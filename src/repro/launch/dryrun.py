import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# the dry-run compiles against 512 VIRTUAL HOST devices by design; pin the
# cpu platform (unless the caller overrides) so a baked-in libtpu never
# hijacks backend discovery and hangs probing for real hardware
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input-shape x mesh) combination this lowers and
COMPILES the real step function against ShapeDtypeStruct inputs (no
allocation), prints memory_analysis() (proves fit) and cost_analysis()
(FLOPs/bytes), parses the partitioned HLO for collective bytes, and stores
one JSON record per combo under --out (resumable; existing records skip).

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k --multi-pod
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.sharding import activate as sharding_activate
from repro.configs.base import (INPUT_SHAPES, InputShape, get_config,
                                list_archs, shape_applicable)
from repro.launch import gnn_steps
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (active_param_count, collective_bytes,
                                   model_flops, roofline)

HBM_PER_CHIP = 16 * 1024 ** 3      # v5e

# gradient-accumulation depth for the train dry-runs: keeps per-device
# activation memory bounded at the assigned global batch (256).  Big
# models use more microbatches; the global batch and numerics are
# unchanged.
def microbatches_for(cfg, shape) -> int:
    if shape.kind != "train":
        return 1
    big = cfg.d_model * cfg.n_layers
    if big >= 3840 * 48:        # >= gemma3-12b scale
        return 8
    if big >= 2048 * 24:
        return 4
    return 2


def _mem_dict(ma) -> Dict[str, int]:
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    return {f: int(getattr(ma, f, 0)) for f in fields}


def _finish(lowered, t0, extra: Dict[str, Any]) -> Dict[str, Any]:
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # jax <= 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    mem = _mem_dict(ma)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    rec = {
        "per_device_flops": flops,
        "per_device_bytes": byt,
        "collective_bytes_per_device": coll,
        "memory": mem,
        "device_bytes_total": mem["argument_size_in_bytes"]
        + mem["temp_size_in_bytes"] + mem["output_size_in_bytes"],
        "fits_hbm": (mem["argument_size_in_bytes"]
                     + mem["temp_size_in_bytes"]
                     + mem["output_size_in_bytes"]) < HBM_PER_CHIP,
        # the CPU backend emulates bf16 math in f32, roughly doubling temp
        # buffers vs a TPU compile (verified on the llama4 breakdown: the
        # dominant temps are f32 copies of bf16 tensors).  Corrected
        # estimate keeps args (real f32 master weights) + temp/2.
        "device_bytes_tpu_estimate": mem["argument_size_in_bytes"]
        + mem["output_size_in_bytes"] + mem["temp_size_in_bytes"] // 2,
        "fits_hbm_tpu_estimate": (mem["argument_size_in_bytes"]
                                  + mem["output_size_in_bytes"]
                                  + mem["temp_size_in_bytes"] // 2)
        < HBM_PER_CHIP,
        "roofline": roofline(flops, byt, coll["total"]),
        "compile_seconds": time.time() - t0,
        "status": "ok",
    }
    rec.update(extra)
    return rec


def dryrun_lm(arch: str, shape: InputShape, multi_pod: bool
              ) -> Dict[str, Any]:
    from repro.models import steps as S
    from repro.models import model as M

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with sharding_activate(mesh):
        params, opt_state = S.abstract_state(
            cfg, mesh, with_opt=(shape.kind == "train"))
        batch = S.batch_specs(cfg, shape, mesh)
        counts = jax.tree.map(lambda x: x, params)  # noqa - keep tree
        if shape.kind == "train":
            mb = microbatches_for(cfg, shape)
            _, train_step = S.make_train_step(cfg, microbatches=mb)
            lowered = jax.jit(train_step).lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            lowered = jax.jit(S.make_prefill_step(cfg)).lower(params, batch)
        else:
            cache = S.cache_shape_specs(cfg, shape, mesh)
            lowered = jax.jit(S.make_serve_step(cfg)).lower(
                params, cache, batch["token"])
        pc = active_param_count(cfg, params)
        mf = model_flops(cfg, params, shape)
        rec = _finish(lowered, t0, {
            "params_total": pc["total"], "params_active": pc["active"],
            "model_flops_global": mf,
        })
    chips = mesh.devices.size
    hlo_global_flops = rec["per_device_flops"] * chips
    rec["model_vs_hlo_flops"] = (rec["model_flops_global"]
                                 / hlo_global_flops
                                 if hlo_global_flops else 0.0)
    rec["chips"] = chips
    return rec


def dryrun_gnn(arch: str, gnn_shape: str, multi_pod: bool) -> Dict[str, Any]:
    import dataclasses
    cfg = get_config(arch)
    if getattr(cfg, "use_agg_kernel", False):
        # the dry-run compiles on the CPU backend: the non-interpret
        # Pallas gather only lowers through Mosaic on real TPUs, so the
        # roofline numbers here come from the (collective-equivalent)
        # einsum path — the kernel itself is exercised by the interpret
        # tests/bench and on hardware
        cfg = dataclasses.replace(cfg, use_agg_kernel=False)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with sharding_activate(mesh):
        params = gnn_steps.gnn_abstract_params(cfg, mesh)
        opt_state = {"step": jax.ShapeDtypeStruct(
            (), jax.numpy.int32,
            sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()))}
        if gnn_shape == "fullgraph_train":
            _, step = gnn_steps.make_fullgraph_step(cfg)
            args = gnn_steps.fullgraph_input_specs(cfg, mesh)
            lowered = jax.jit(step).lower(params, opt_state, *args)
            tokens = cfg.n_nodes
        else:
            _, step = gnn_steps.make_minibatch_step(cfg)
            feats, masks, weights, self_w, labels = \
                gnn_steps.minibatch_input_specs(cfg, mesh)
            lowered = jax.jit(step).lower(params, opt_state, feats, masks,
                                          weights, self_w, labels)
            tokens = cfg.batch_size
        rec = _finish(lowered, t0, {"gnn_nodes_per_step": tokens})
    rec["chips"] = mesh.devices.size
    return rec


GNN_SHAPES = ("fullgraph_train", "minibatch_train")


def combos(archs=None, shapes=None, meshes=("single", "multi")):
    archs = archs or list_archs()
    for arch in archs:
        cfg = get_config(arch)
        if cfg.family == "gnn":
            names = shapes or GNN_SHAPES
            for s in names:
                if s not in GNN_SHAPES:
                    continue
                for mp in meshes:
                    yield arch, s, mp == "multi", None
            continue
        names = shapes or list(INPUT_SHAPES)
        for s in names:
            if s not in INPUT_SHAPES:
                continue
            ok, why = shape_applicable(cfg, INPUT_SHAPES[s])
            for mp in meshes:
                yield arch, s, mp == "multi", (None if ok else why)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            skip_reason: Optional[str]) -> Dict[str, Any]:
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16"}
    if skip_reason:
        return {**meta, "status": "skipped", "reason": skip_reason}
    try:
        cfg = get_config(arch)
        if cfg.family == "gnn":
            rec = dryrun_gnn(arch, shape_name, multi_pod)
        else:
            rec = dryrun_lm(arch, INPUT_SHAPES[shape_name], multi_pod)
        rec.update(meta)
        return rec
    except Exception as e:
        # deliberately broad: the dry-run matrix records every
        # arch x shape outcome side by side, so ANY per-cell failure
        # becomes an "error" row instead of aborting the whole report
        return {**meta, "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append")
    ap.add_argument("--shape", action="append")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = ("single", "multi")
    if args.multi_pod and not args.single_pod:
        meshes = ("multi",)
    elif args.single_pod and not args.multi_pod:
        meshes = ("single",)

    os.makedirs(args.out, exist_ok=True)
    todo = list(combos(args.arch, args.shape, meshes))
    print(f"dry-run: {len(todo)} combos -> {args.out}", flush=True)
    for arch, shape_name, mp, skip in todo:
        tag = f"{arch}__{shape_name}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip-existing] {tag}", flush=True)
            continue
        t0 = time.time()
        rec = run_one(arch, shape_name, mp, skip)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" dom={r['dominant']} bound={r['bound_s']:.4f}s"
                     f" fits={rec['fits_hbm']}"
                     f" mem={rec['device_bytes_total']/2**30:.2f}GiB")
        elif status == "error":
            extra = " " + rec["error"][:120]
        print(f"[{status}] {tag} ({time.time()-t0:.0f}s){extra}", flush=True)


if __name__ == "__main__":
    main()
