"""Roofline-term extraction from compiled dry-run artifacts (deliverable g).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` on an SPMD module reports PER-DEVICE flops /
bytes (verified empirically), so the per-chip terms divide by one chip's
peak.  collective_bytes comes from parsing the partitioned HLO: we build a
name -> result-bytes symbol table over every instruction and sum the
OPERAND sizes of each collective op (per spec).

TPU v5e hardware constants.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

# --- TPU v5e ---------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DEF_RE = re.compile(
    r"%([\w.\-]+)\s*=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\])")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * nb


def _tuple_bytes(inner: str) -> int:
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(inner))


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device WIRE bytes of every collective, by type (ring model,
    large-N limit):
        all-reduce       ~ 2 x operand   (reduce-scatter + all-gather)
        reduce-scatter   ~ 1 x operand
        all-gather       ~ 1 x OUTPUT    (operand is just the local shard)
        all-to-all       ~ 1 x operand
        collective-permute ~ 1 x operand
    """
    sizes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        name, tup, dt, dims = m.groups()
        sizes[name] = _tuple_bytes(tup) if tup is not None \
            else _shape_bytes(dt, dims)

    out = {c: 0 for c in COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        mm = re.search(r"%[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\][^ ]*)\s*"
                       r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                       r"collective-permute)(?:-start)?\(([^)]*)\)", line)
        if not mm:
            continue
        result_ty, kind, operands = mm.groups()
        ob = 0
        for op in re.findall(r"%([\w.\-]+)", operands):
            ob += sizes.get(op, 0)
        if kind == "all-gather":
            if result_ty.startswith("("):
                b = _tuple_bytes(result_ty)
            else:
                sm = _SHAPE_RE.match(result_ty)
                b = _shape_bytes(*sm.groups()) if sm else ob
        elif kind == "all-reduce":
            b = 2 * ob
        else:
            b = ob
        out[kind] += b
        out["total"] += b
    return out


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float) -> Dict[str, Any]:
    t_compute = flops_per_dev / PEAK_FLOPS
    t_memory = bytes_per_dev / HBM_BW
    t_collective = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_collective)
    return {**terms, "dominant": dominant.replace("_s", ""),
            "bound_s": bound,
            "compute_fraction": t_compute / bound if bound else 0.0}


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params
# ---------------------------------------------------------------------------

def count_params(tree, predicate=None) -> int:
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if predicate is None or predicate(path):
            n = 1
            for s in leaf.shape:
                n *= s
            total += n
    return total


def active_param_count(cfg, params_tree) -> Dict[str, int]:
    """Total and ACTIVE (top-k of MoE experts) non-embedding params."""
    import jax

    def names(path):
        return [p.key for p in path if hasattr(p, "key")]

    total = count_params(params_tree)
    embed = count_params(
        params_tree, lambda p: names(p) and names(p)[-1] in ("embed",
                                                             "lm_head"))
    moe = count_params(params_tree, lambda p: "moe" in names(p))
    router = count_params(
        params_tree, lambda p: "moe" in names(p)
        and names(p)[-1] == "router")
    n_e = max(cfg.n_experts, 1)
    active_moe = router + (moe - router) * min(cfg.top_k, n_e) // n_e
    body = total - embed
    return {"total": total, "embedding": embed,
            "active": body - moe + active_moe,
            "dense_equiv": body}


def model_flops(cfg, params_tree, shape) -> float:
    """6·N_active·D for training, 2·N_active·D for inference."""
    counts = active_param_count(cfg, params_tree)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * counts["active"] * tokens


# ---------------------------------------------------------------------------
# Analytic FLOP model (matmul-dominated terms, per global step).
#
# Needed because XLA's cost_analysis counts while-loop bodies ONCE (verified
# empirically: a scan of 10 matmuls reports 1 matmul of flops), so any
# scanned-layer model under-reports HLO_FLOPs by roughly the layer count.
# The analytic model reflects what this implementation actually computes —
# including the chunked-causal mask waste (global-attention scores are
# computed for the full rectangle, not the causal half).
# ---------------------------------------------------------------------------

def analytic_flops(cfg, shape) -> float:
    from repro import sharding as sh

    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    t = b * s
    d = cfg.d_model
    fwd = 0.0

    def attn_layer(ctx) -> float:
        hd = cfg.resolved_head_dim
        hq = sh.padded_heads(cfg.n_heads)
        proj = 2 * t * d * hd * (hq + 2 * cfg.n_kv_heads) \
            + 2 * t * hq * hd * d
        scores = 4 * t * ctx * hq * hd
        return proj + scores

    def mlp() -> float:
        if cfg.n_experts:
            cap = max(1, int(cfg.capacity_factor * min(cfg.moe_group, s)
                             / cfg.n_experts))
            router = 2 * t * d * cfg.n_experts
            groups = t // max(min(cfg.moe_group, s), 1)
            dispatch = 2 * 2 * t * cfg.n_experts * cap * d
            expert_tokens = groups * cfg.n_experts * cap
            ffn = 6 * min(expert_tokens, t * cfg.top_k) * d * cfg.d_ff \
                if cfg.capacity_factor <= 2 else 6 * t * cfg.top_k * d \
                * cfg.d_ff
            return router + dispatch + ffn
        return 6 * t * d * cfg.d_ff

    def mamba_layer() -> float:
        d_in = cfg.ssm_expand * d
        h = d_in // cfg.ssm_head_dim
        n = cfg.ssm_state
        p = cfg.ssm_head_dim
        proj = 2 * t * d * (2 * d_in + 2 * n + h) + 2 * t * d_in * d
        if shape.kind == "decode":
            ssd = 4 * b * h * p * n
        else:
            c = min(256, s)
            nz = s // c
            intra = b * nz * (2 * c * c * n + 2 * c * c * h * p)
            states = b * nz * (2 * c * h * p * n) * 2
            ssd = intra + states
        return proj + ssd

    for lt in cfg.pattern:
        if lt == "mamba":
            fwd += mamba_layer()
            continue
        if shape.kind == "decode":
            cap = shape.seq_len if lt in ("attn", "shared_attn") \
                else min(cfg.sliding_window, shape.seq_len)
            ctx = cap
        elif lt == "local" and cfg.sliding_window:
            ctx = min(cfg.sliding_window + cfg.q_chunk, s)
        else:
            ctx = s            # full rectangle (mask waste) per q chunk
        fwd += attn_layer(ctx) + mlp()

    if cfg.n_enc_layers and shape.kind != "decode":
        te = b * cfg.enc_seq
        enc_attn = (2 * te * d * cfg.resolved_head_dim
                    * (sh.padded_heads(cfg.n_heads) + 2 * cfg.n_kv_heads)
                    + 2 * te * d * d
                    + 4 * te * cfg.enc_seq
                    * sh.padded_heads(cfg.n_heads) * cfg.resolved_head_dim)
        fwd += cfg.n_enc_layers * (enc_attn + 6 * te * d * cfg.d_ff)
        # decoder cross-attention over enc_seq keys
        fwd += cfg.n_layers * 4 * t * cfg.enc_seq \
            * sh.padded_heads(cfg.n_heads) * cfg.resolved_head_dim

    vp = ((cfg.vocab_size + sh.MODEL_PAR - 1) // sh.MODEL_PAR) \
        * sh.MODEL_PAR
    head = 2 * t * d * vp
    total_fwd = fwd + head
    return total_fwd * (3.0 if shape.kind == "train" else 1.0)
