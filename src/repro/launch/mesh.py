"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across pods (batch shards over
pod x data), so cross-pod traffic is gradient all-reduce only.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """jax >= 0.5 takes axis_types (AxisType.Auto); older jax (the pinned
    0.4.x) has neither the kwarg nor the enum — Auto is its only mode."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(model_par: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = n // model_par
    return jax.make_mesh((data, model_par), ("data", "model"),
                         **_mesh_kwargs(2))
