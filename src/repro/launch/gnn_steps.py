"""Distributed GNN step functions for the dry-run + production launcher.

Full-graph training (the paper's paradigm 1) at production scale:
  * node arrays (features, ELL neighbor ids/weights, labels) shard over the
    data axes ("pod" x "data"); the cross-partition neighbor gather becomes
    XLA all-gathers of the feature table — the communication the paper
    attributes to full-graph systems (DistGNN/Sancus), measured in the
    roofline collective term.
  * GNN weights are small and stay replicated (tensor parallelism buys
    nothing at hidden=256; the model axis idles for GNN full-graph).

Mini-batch training (paradigm 2) is pure data parallelism over the sampled
fan-out trees; host sampling is the infeed.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.optim import sgd


def gnn_abstract_params(cfg: GNNConfig, mesh):
    key = jax.random.key(0)
    shapes = jax.eval_shape(
        lambda k: G.init_gnn(k, cfg, cfg.feat_dim), key)
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=sh.named((None,) * l.ndim, mesh)),
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def make_fullgraph_step(cfg: GNNConfig):
    opt = sgd(0.1)

    def step(params, opt_state, feats, idx, w, w_self, labels):
        def loss_fn(p):
            logits = G.full_graph_forward(p, cfg, feats, idx, w, w_self)
            return G.gnn_loss(logits, labels, cfg.loss, cfg.n_classes)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt2 = opt.update(grads, opt_state, params)
        return params2, opt2, loss

    return opt, step


def fullgraph_input_specs(cfg: GNNConfig, mesh) -> Tuple[Any, ...]:
    n, k, r = cfg.n_nodes, cfg.max_degree, cfg.feat_dim
    f32, i32 = jnp.float32, jnp.int32
    sds = lambda shp, dt, spec: jax.ShapeDtypeStruct(
        shp, dt, sharding=sh.named(spec, mesh))
    return (
        sds((n, r), f32, (sh.NODES, None)),       # feats
        sds((n, k), i32, (sh.NODES, None)),       # ELL neighbor ids
        sds((n, k), f32, (sh.NODES, None)),       # ã weights
        sds((n,), f32, (sh.NODES,)),              # self-loop weights
        sds((n,), i32, (sh.NODES,)),              # labels
    )


def make_minibatch_step(cfg: GNNConfig):
    opt = sgd(0.1)

    def step(params, opt_state, feats, masks, weights, self_w, labels):
        def loss_fn(p):
            logits = G.minibatch_forward(p, cfg, feats, masks, weights,
                                         self_w)
            return G.gnn_loss(logits, labels, cfg.loss, cfg.n_classes)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt2 = opt.update(grads, opt_state, params)
        return params2, opt2, loss

    return opt, step


def minibatch_input_specs(cfg: GNNConfig, mesh) -> Tuple[Any, ...]:
    b, r = cfg.batch_size, cfg.feat_dim
    f32, i32 = jnp.float32, jnp.int32
    sds = lambda shp, dt, spec: jax.ShapeDtypeStruct(
        shp, dt, sharding=sh.named(spec, mesh))
    feats, masks, weights, self_w = [], [], [], []
    shape = (b,)
    feats.append(sds(shape + (r,), f32, (sh.BATCH, None)))
    self_w.append(sds(shape, f32, (sh.BATCH,)))
    for beta in cfg.fanout:
        edge = shape + (beta,)
        masks.append(sds(edge, f32, (sh.BATCH,) + (None,) * len(shape)))
        weights.append(sds(edge, f32, (sh.BATCH,) + (None,) * len(shape)))
        shape = edge
        feats.append(sds(shape + (r,), f32,
                         (sh.BATCH,) + (None,) * len(shape)))
        self_w.append(sds(shape, f32, (sh.BATCH,) + (None,) * (len(shape) - 1)))
    labels = sds((b,), i32, (sh.BATCH,))
    return feats, masks, weights, self_w, labels
