from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointCorruptError, CheckpointDtypeError, CheckpointError,
    CheckpointKeyError, CheckpointShapeError, available_steps,
    latest_step, load_metadata, restore_checkpoint, save_checkpoint)
