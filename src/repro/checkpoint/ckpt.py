"""Crash-safe flat-npz pytree checkpointing: manifest, checksums,
retention, atomic writes.

Leaves are addressed by their tree path ("runs/0/attn/wq", ...), so a
checkpoint is restorable into any pytree with the same structure — and is
readable with plain numpy for inspection.

Durability contract (single writer per directory):

- the npz is written to a ``*.tmp`` file, **fsync'd**, then atomically
  ``os.replace``d into place; ``meta_*.json`` follows the same tmp +
  replace protocol, so a reader never sees a torn file;
- a ``MANIFEST.json`` (also written atomically) records each COMPLETED
  step with the npz's sha256 — it is the last thing written, so a save
  killed at any point leaves the directory restorable at the previous
  step (``latest_step`` trusts the manifest when one exists and never
  reports a half-finished save);
- stale ``*.tmp`` files left by a crashed writer are garbage-collected
  at the start of the next save, so they can never race or shadow a
  real checkpoint;
- ``keep_last=k`` retains only the newest k steps: the manifest is
  rewritten FIRST, then the retired files are deleted, so a crash
  mid-retention strands at worst unreferenced files (cleaned by the
  next retention pass), never a referenced-but-deleted step.

``restore_checkpoint`` verifies the recorded checksum (corruption ->
``CheckpointCorruptError``) and raises typed, leaf-naming errors on
structure drift: ``CheckpointKeyError`` (missing/extra leaves),
``CheckpointShapeError``, ``CheckpointDtypeError`` — real exceptions,
not ``assert``s that vanish under ``python -O``.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import tempfile
from typing import Any, Dict, List, Optional

import jax
import numpy as np

MANIFEST = "MANIFEST.json"
_CKPT_RE = re.compile(r"ckpt_(\d+)\.npz$")


class CheckpointError(RuntimeError):
    """Base class for checkpoint layer failures."""


class CheckpointCorruptError(CheckpointError):
    """Stored checksum does not match the bytes on disk."""


class CheckpointKeyError(CheckpointError):
    """Checkpoint and restore-target trees have different leaf sets."""


class CheckpointShapeError(CheckpointError):
    """A stored leaf's shape does not match the restore target's."""


class CheckpointDtypeError(CheckpointError):
    """A stored leaf's dtype does not match the restore target's."""


def _maybe_crash(name: str) -> None:
    """Chaos-test failpoint (inert unless ``core.faults`` armed it).
    Imported lazily so the checkpoint layer keeps zero import-time
    coupling to the core package."""
    try:
        from repro.core import faults
    except ImportError:                      # pragma: no cover
        return
    faults.maybe_crash(name)


def _path_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    return {_path_name(path): np.asarray(leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]}


def _flatten_paths(tree: Any):
    return [(_path_name(path), leaf)
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]]


# ---------------------------------------------------------------------------
# Low-level durable-write helpers
# ---------------------------------------------------------------------------

def _fsync_dir(directory: str) -> None:
    """Persist renames within ``directory`` (best effort off POSIX)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:                          # pragma: no cover
        return
    try:
        os.fsync(fd)
    except OSError:                          # pragma: no cover
        pass
    finally:
        os.close(fd)


def _write_json_atomic(path: str, obj: Any) -> None:
    """tmp + fsync + ``os.replace``: a reader sees the old file or the
    new one, never a torn write."""
    directory = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=2, default=str)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _gc_stale_tmp(directory: str) -> List[str]:
    """Remove ``*.tmp`` files left behind by a crashed writer.  Called
    at the start of every save (single-writer directories, so any tmp
    present then is stale) — crashed writes can therefore never shadow,
    race, or be mistaken for a real checkpoint."""
    removed = []
    for fn in os.listdir(directory):
        if fn.endswith(".tmp"):
            try:
                os.unlink(os.path.join(directory, fn))
                removed.append(fn)
            except OSError:                  # pragma: no cover
                pass
    return removed


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------

def _read_manifest(directory: str) -> Optional[dict]:
    path = os.path.join(directory, MANIFEST)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            m = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"unreadable checkpoint manifest {path}: {e}") from e
    if not isinstance(m, dict) or "steps" not in m:
        raise CheckpointCorruptError(
            f"malformed checkpoint manifest {path}: no 'steps' table")
    return m


def _scan_steps(directory: str) -> List[int]:
    return sorted(int(m.group(1)) for fn in os.listdir(directory)
                  if (m := _CKPT_RE.match(fn)))


def _load_or_adopt_manifest(directory: str) -> dict:
    """Existing manifest, or a fresh one ADOPTING any pre-manifest
    checkpoints already in the directory (so upgrading a directory
    written by the old format never hides or GC's its steps)."""
    m = _read_manifest(directory)
    if m is not None:
        return m
    m = {"format": 1, "steps": {}}
    for step in _scan_steps(directory):
        fn = f"ckpt_{step:08d}.npz"
        m["steps"][str(step)] = {
            "file": fn,
            "sha256": _sha256(os.path.join(directory, fn)),
            "has_meta": os.path.exists(
                os.path.join(directory, f"meta_{step:08d}.json")),
        }
    return m


def _write_manifest(directory: str, manifest: dict) -> None:
    _write_json_atomic(os.path.join(directory, MANIFEST), manifest)
    _fsync_dir(directory)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None,
                    keep_last: Optional[int] = None) -> str:
    """Durably write ``tree`` as step ``step``.

    Write order (each stage atomic, manifest last): npz -> meta ->
    manifest -> retention.  A crash at ANY point leaves ``latest_step``
    reporting the previous completed step and the directory fully
    restorable there.  ``keep_last`` retains only the newest k manifest
    steps (None/0 = keep all).
    """
    os.makedirs(directory, exist_ok=True)
    _gc_stale_tmp(directory)
    manifest = _load_or_adopt_manifest(directory)

    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        _maybe_crash("ckpt.before_npz_rename")
        os.replace(tmp, path)                 # atomic
    except Exception:
        # recoverable failure (disk full, ...): clean our own tmp up.
        # BaseException (KeyboardInterrupt, SimulatedCrash) falls
        # through like real process death — the next save's
        # _gc_stale_tmp reaps the leftover.
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _maybe_crash("ckpt.after_npz_rename")

    if metadata is not None:
        _write_json_atomic(
            os.path.join(directory, f"meta_{step:08d}.json"), metadata)
    _maybe_crash("ckpt.after_meta")

    manifest["steps"][str(step)] = {
        "file": os.path.basename(path),
        "sha256": _sha256(path),
        "has_meta": metadata is not None,
    }
    _write_manifest(directory, manifest)

    if keep_last:
        _retire_old(directory, manifest, int(keep_last))
    return path


def _retire_old(directory: str, manifest: dict, keep_last: int) -> None:
    """Drop all but the newest ``keep_last`` steps: manifest first (the
    source of truth shrinks atomically), files second, then a sweep for
    unreferenced leftovers older than the retained window."""
    steps = sorted(int(s) for s in manifest["steps"])
    if keep_last < 1 or len(steps) <= keep_last:
        return
    drop = steps[:-keep_last]
    for s in drop:
        del manifest["steps"][str(s)]
    _write_manifest(directory, manifest)
    kept_min = min(int(s) for s in manifest["steps"])
    for fn in os.listdir(directory):
        m = _CKPT_RE.match(fn) or re.match(r"meta_(\d+)\.json$", fn)
        if m and int(m.group(1)) < kept_min:
            try:
                os.unlink(os.path.join(directory, fn))
            except OSError:                  # pragma: no cover
                pass
    _fsync_dir(directory)


def available_steps(directory: str) -> List[int]:
    """Completed steps, oldest first (manifest-backed when present)."""
    if not os.path.isdir(directory):
        return []
    m = _read_manifest(directory)
    if m is not None:
        return sorted(int(s) for s in m["steps"])
    return _scan_steps(directory)


def latest_step(directory: str) -> Optional[int]:
    """Newest COMPLETED step.  With a manifest present, only steps the
    manifest records count — an npz orphaned by a crash between its
    rename and the manifest update is invisible, so readers resume from
    the last save that actually finished."""
    steps = available_steps(directory)
    return max(steps) if steps else None


def load_metadata(directory: str, step: Optional[int] = None
                  ) -> Optional[dict]:
    """The ``metadata`` dict saved alongside step ``step`` (default:
    latest), or None when the step has no meta file."""
    step = latest_step(directory) if step is None else step
    if step is None:
        return None
    path = os.path.join(directory, f"meta_{step:08d}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None,
                       verify: bool = True) -> Any:
    """Restore into the structure of ``like`` (arrays or
    ShapeDtypeStructs).  ``verify`` checks the manifest's sha256 before
    deserializing (skipped for pre-manifest directories, which recorded
    none)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(f"checkpoint step {step} not found: {path}")

    if verify:
        m = _read_manifest(directory)
        entry = None if m is None else m["steps"].get(str(step))
        if entry is not None and entry.get("sha256"):
            digest = _sha256(path)
            if digest != entry["sha256"]:
                raise CheckpointCorruptError(
                    f"checksum mismatch for {path}: manifest records "
                    f"{entry['sha256'][:12]}..., file hashes "
                    f"{digest[:12]}... — the checkpoint is corrupt")

    with np.load(path) as data:
        flat_like = _flatten_paths(like)
        want = [name for name, _ in flat_like]
        have = set(data.files)
        missing = [n for n in want if n not in have]
        extra = sorted(have - set(want))
        if missing or extra:
            raise CheckpointKeyError(
                f"checkpoint {path} does not match the restore target: "
                f"missing leaves {missing or 'none'}, "
                f"unexpected leaves {extra or 'none'} — was it saved "
                f"from a different model/optimizer structure?")
        leaves = []
        for name, leaf in flat_like:
            arr = data[name]
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if arr.shape != want_shape:
                raise CheckpointShapeError(
                    f"leaf {name!r}: checkpoint shape {arr.shape} != "
                    f"restore target shape {want_shape}")
            want_dtype = getattr(leaf, "dtype", None)
            if want_dtype is not None \
                    and arr.dtype != np.dtype(want_dtype):
                raise CheckpointDtypeError(
                    f"leaf {name!r}: checkpoint dtype {arr.dtype} != "
                    f"restore target dtype {np.dtype(want_dtype)}")
            leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)
