"""Flat-npz pytree checkpointing with step management and atomic writes.

Leaves are addressed by their tree path ("runs/0/attn/wq", ...), so a
checkpoint is restorable into any pytree with the same structure — and is
readable with plain numpy for inspection.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict:
    flat = {}

    def name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[name(path)] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)                     # atomic
    if metadata is not None:
        with open(os.path.join(directory, f"meta_{step:08d}.json"),
                  "w") as f:
            json.dump(metadata, f, indent=2, default=str)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Any,
                       step: Optional[int] = None) -> Any:
    """Restore into the structure of `like` (arrays or ShapeDtypeStructs)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    flat_like = _flatten_paths(like)
    leaves = []
    for name, leaf in flat_like:
        arr = data[name]
        assert arr.shape == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _flatten_paths(tree: Any):
    out = []

    def name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append((name(path), leaf))
    return out
