"""llama4-maverick-400b-a17b  [moe] — MoE 128 experts top-1, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 128e top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E] (assigned citation; maverick variant)
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple(("local", "local", "local", "attn") * 12)  # 48 layers


def full_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        n_experts=128,
        top_k=1,
        layer_pattern=_PATTERN,
        sliding_window=8192,
        rope_theta=500_000.0,
        source="hf:meta-llama/Llama-4-Scout-17B-16E (maverick 128e)",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=4,     # reduced (<=4 experts per smoke rules)
        top_k=1,
        layer_pattern=("local", "attn"),
        sliding_window=64,
        q_chunk=32,
        kv_chunk=32,
        moe_group=32,
        dtype="float32",
        source="(reduced)",
    )
