"""gnn-papers100m-like  [gnn] — BONUS config: the paper's own system at
production scale, included in the dry-run matrix beyond the assigned 10.

Mirrors ogbn-papers100M's regime scaled to fit the dry-run mesh:
16M nodes, 128-dim features, 172 classes, GraphSAGE-mean 2-layer,
fan-out (15, 10) / batch 8192 for mini-batch; ELL max_degree=32 for
full-graph.  [paper: Liu et al. 2026; dataset: Hu et al. 2020]
"""
from repro.configs.base import GNNConfig


def full_config() -> GNNConfig:
    return GNNConfig(
        name="gnn-papers100m",
        model="graphsage",
        n_nodes=16_777_216,
        feat_dim=128,
        hidden=256,
        n_classes=172,
        n_layers=2,
        fanout=(15, 10),
        batch_size=8192,
        max_degree=32,
        dtype="bfloat16",   # aggregation traffic dtype (§Perf H1)
        # Real-TPU fast path: the batch-tiled, double-buffered Pallas
        # gather (compiled, not interpret mode) — mesh-ready since the
        # shard_map partitioning over the NODES axis, so both sharded
        # sources run it on N devices.  When hardware is around, record
        # the HBM-bound step times into the BENCH_engine.json trajectory
        # (`make bench-engine-baseline` on the TPU host) next to the
        # CPU-interpret rows; the launch/dryrun.py CPU compile forces
        # the einsum path instead (Mosaic won't lower off-TPU).
        use_agg_kernel=True,
        agg_interpret=False,
        source="Liu et al. 2026 / ogbn-papers100M (Hu et al. 2020)",
    )


def smoke_config() -> GNNConfig:
    return GNNConfig(
        name="gnn-papers100m",
        model="graphsage",
        n_nodes=512,
        feat_dim=32,
        hidden=64,
        n_classes=8,
        n_layers=2,
        fanout=(5, 3),
        batch_size=32,
        max_degree=16,
        source="(reduced)",
    )
