"""stablelm-1.6b  [dense] — MHA (kv=heads).

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab_size=100_352,
        source="hf:stabilityai/stablelm-2-1_6b",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        q_chunk=32,
        kv_chunk=32,
        dtype="float32",
        source="(reduced)",
    )
