"""granite-3-2b  [dense] — GQA.

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
[hf:ibm-granite/granite-3.0-2b-base]
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=49_155,
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        tie_embeddings=True,
        q_chunk=32,
        kv_chunk=32,
        dtype="float32",
        source="(reduced)",
    )
