"""mamba2-130m  [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 vocab=50280 ssm_state=128.  [arXiv:2405.21060]
d_inner = 2*d_model = 1536, head_dim 64 -> 24 SSD heads.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        layer_pattern=("mamba",) * 24,
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        layer_pattern=("mamba",) * 2,
        tie_embeddings=True,
        dtype="float32",
        source="arXiv:2405.21060 (reduced)",
    )
