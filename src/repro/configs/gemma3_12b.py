"""gemma3-12b  [dense] — 5:1 local:global interleave, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
[hf:google/gemma-3-1b-pt]
head_dim=256 per the gemma3 model card (not d_model/n_heads).
sliding_window=1024 (gemma3 local layers).
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple(("local",) * 5 + ("attn",)) * 8  # 48 layers, 5:1


def full_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262_144,
        mlp_act="gelu",
        layer_pattern=_PATTERN,
        sliding_window=1024,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        source="hf:google/gemma-3-1b-pt",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        mlp_act="gelu",
        layer_pattern=("local", "attn"),
        sliding_window=64,
        q_chunk=32,
        kv_chunk=32,
        tie_embeddings=True,
        dtype="float32",
        source="hf:google/gemma-3-1b-pt (reduced)",
    )
