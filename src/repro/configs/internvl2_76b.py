"""internvl2-76b  [vlm] — InternViT (STUB) + LLM backbone (implemented).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  [arXiv:2404.16821]

Backbone only: the InternViT-6B vision encoder + MLP projector is a stub;
``input_specs()`` supplies precomputed patch embeddings (batch, frontend_seq,
d_model) prepended to the text sequence (1024 visual tokens ~ 4 tiles x 256).
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128_256,
        frontend_seq=1024,
        rope_theta=1_000_000.0,
        source="arXiv:2404.16821",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=8,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        frontend_seq=16,
        q_chunk=32,
        kv_chunk=32,
        dtype="float32",
        source="arXiv:2404.16821 (reduced)",
    )
