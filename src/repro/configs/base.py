"""Config system: architectures, input shapes, registry.

Every assigned architecture gets a module ``configs/<id>.py`` exporting
``full_config()`` (the exact assigned spec) and ``smoke_config()``
(a reduced same-family variant: <=2 layers, d_model<=512, <=4 experts)
plus registration into the global registry.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm | gnn
    n_layers: int
    d_model: int
    n_heads: int = 0                # query heads (0 for attn-free)
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads
    # --- MLP ---
    mlp_act: str = "silu"           # "silu" (SwiGLU) | "gelu" (GeGLU)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    # --- layer pattern ---
    # pattern tokens: "attn" (global), "local" (sliding window), "mamba",
    # "shared_attn" (zamba2-style weight-shared attention block).
    # None => ("attn",) * n_layers.
    layer_pattern: Optional[Tuple[str, ...]] = None
    sliding_window: int = 0
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                # encoder frames (stub frontend output length)
    # --- modality frontend stub (vlm) ---
    frontend_seq: int = 0           # patch embeddings prepended to the text seq
    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    tie_embeddings: bool = False
    # attention chunking for the online-softmax scan
    q_chunk: int = 512
    kv_chunk: int = 1024
    # mlp/moe group size for capacity routing (tokens per routing group)
    moe_group: int = 256
    source: str = ""                # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            assert len(self.layer_pattern) == self.n_layers, (
                f"{self.name}: pattern length {len(self.layer_pattern)} != "
                f"n_layers {self.n_layers}")
            return self.layer_pattern
        return ("attn",) * self.n_layers

    @property
    def is_sub_quadratic(self) -> bool:
        """True if every layer has bounded receptive field (SSM or window)."""
        return all(
            t in ("mamba",) or (t in ("local",) and self.sliding_window > 0)
            for t in self.pattern
        ) or self.supports_long_decode

    @property
    def supports_long_decode(self) -> bool:
        """long_500k eligibility: SSM/hybrid, or dense with a sliding-window /
        chunked-local variant on most layers (global layers keep a
        model-sharded KV, which is memory- not compute-quadratic at decode)."""
        toks = set(self.pattern)
        if toks <= {"mamba"}:
            return True
        if "mamba" in toks:                      # hybrid
            return True
        if "local" in toks and self.sliding_window > 0:
            return True
        return False

    @property
    def has_decode(self) -> bool:
        """Encoder-only / pure-encoder families would return False; all our
        assigned archs are decoders (whisper has a decoder stack)."""
        return True

    def validate(self) -> None:
        assert self.d_model > 0 and self.n_layers > 0
        if self.family not in ("ssm",):
            assert self.vocab_size > 0
        for t in self.pattern:
            assert t in ("attn", "local", "mamba", "shared_attn"), t
        if "local" in self.pattern:
            assert self.sliding_window > 0


# ---------------------------------------------------------------------------
# GNN configuration (the paper's own system)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str = "gnn"
    model: str = "graphsage"        # gcn | graphsage | gat
    n_nodes: int = 0
    feat_dim: int = 0
    hidden: int = 256
    n_classes: int = 0
    n_layers: int = 2
    fanout: Tuple[int, ...] = (15, 10)   # β per hop (mini-batch)
    batch_size: int = 1024               # b (mini-batch)
    max_degree: int = 32                 # ELL padding for full-graph
    gat_heads: int = 4
    dtype: str = "float32"
    loss: str = "ce"                     # ce | mse
    # --- Pallas neighbor-aggregation kernel (kernels/neighbor_agg) ---
    # Routes the Ã-weighted aggregation of gcn/graphsage through the
    # batch-tiled software-gather kernel in BOTH forward paths.  GAT keeps
    # the einsum path (per-edge softmax attention is not a weighted sum).
    use_agg_kernel: bool = False
    agg_interpret: bool = True           # interpret mode on CPU; False on TPU
    agg_b_tile: int = 8
    agg_d_tile: int = 128
    agg_k_slab: int = 4
    # --- feature-table layout (kernels/neighbor_agg/featshard) ---
    # "replicated": every device holds the full [n, d] gather source (the
    # PR-5 sharded kernel's layout).  "sharded": the table rows over the
    # NODES mesh axis with a degree-ordered hot cache of the top
    # feat_cache_rows high-degree rows replicated per shard — per-device
    # memory drops to n·d/shards + C·d and cold rows move via one
    # compacted all_gather overlapped with the shard-local aggregation.
    # Takes effect on the sharded kernel paths (sharded sources +
    # use_agg_kernel); einsum/unsharded paths ignore it.
    feats_layout: str = "replicated"     # replicated | sharded
    feat_cache_rows: int = -1            # -1 auto (n//8) | 0 off | explicit C
    source: str = ""

    @property
    def has_decode(self) -> bool:
        return False

    def validate(self) -> None:
        """Reject bad (b, β) grids and kernel tilings up front — a zero
        tile or fan-out otherwise surfaces as an opaque Pallas shape
        error deep inside the aggregation kernel."""
        def req(cond: bool, msg: str) -> None:
            if not cond:
                raise ValueError(f"GNNConfig {self.name!r}: {msg}")
        req(self.model in ("gcn", "graphsage", "gat"),
            f"unknown model {self.model!r}")
        req(self.n_layers > 0, f"n_layers must be > 0, got {self.n_layers}")
        req(self.hidden > 0, f"hidden must be > 0, got {self.hidden}")
        req(len(self.fanout) == self.n_layers,
            f"fanout {self.fanout} must have one β per layer "
            f"(n_layers={self.n_layers})")
        req(all(int(b) > 0 for b in self.fanout),
            f"fan-outs must be positive, got {self.fanout}")
        req(self.batch_size > 0,
            f"batch_size must be > 0, got {self.batch_size}")
        req(self.n_nodes <= 0 or self.batch_size <= self.n_nodes,
            f"batch_size must not exceed the graph "
            f"(b={self.batch_size} > n_nodes={self.n_nodes}); the engine "
            f"pads b > n_train, but b > n can only be a grid typo")
        req(self.max_degree > 0,
            f"max_degree must be > 0, got {self.max_degree}")
        if self.model == "gat":
            req(self.gat_heads > 0,
                f"gat_heads must be > 0, got {self.gat_heads}")
        for f in ("agg_b_tile", "agg_d_tile", "agg_k_slab"):
            req(getattr(self, f) > 0,
                f"{f} must be > 0, got {getattr(self, f)}")
        req(self.feats_layout in ("replicated", "sharded"),
            f"unknown feats_layout {self.feats_layout!r} "
            f"(expected 'replicated' or 'sharded')")
        req(self.feat_cache_rows >= -1,
            f"feat_cache_rows must be -1 (auto), 0 (off) or a positive "
            f"cache size, got {self.feat_cache_rows}")


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k",    "train",   4_096,   256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  InputShape("decode_32k",  "decode",  32_768,  128),
    "long_500k":   InputShape("long_500k",   "decode",  524_288, 1),
}


def shape_applicable(cfg, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) should run, and why not if skipped."""
    if cfg.family == "gnn":
        return False, (
            "GNN configs use their own dry-run shapes (fullgraph_step / "
            "minibatch_step); see launch/dryrun.py")
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False, (
            f"{cfg.name} is a pure full-attention stack; long_500k needs "
            "sub-quadratic attention (see DESIGN.md §Arch-applicability)")
    if shape.kind == "decode" and not cfg.has_decode:
        return False, f"{cfg.name} has no decode step"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = [
    "llama4_scout_17b_a16e",
    "gemma_7b",
    "whisper_medium",
    "llama4_maverick_400b_a17b",
    "mamba2_130m",
    "gemma3_12b",
    "granite_3_2b",
    "stablelm_1_6b",
    "zamba2_7b",
    "internvl2_76b",
    "gnn_papers100m",        # bonus: the paper's own system at scale
]

_REGISTRY: Dict[str, Any] = {}


def _load() -> None:
    if _REGISTRY:
        return
    for mod_name in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        cfg = mod.full_config()
        _REGISTRY[cfg.name] = mod


def list_archs() -> Tuple[str, ...]:
    _load()
    return tuple(_REGISTRY.keys())


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    _load()
    key = name.replace("_", "-")
    for k, mod in _REGISTRY.items():
        if k == key or k.replace("-", "_") == name:
            cfg = mod.smoke_config() if smoke else mod.full_config()
            cfg.validate()
            return cfg
    raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
