"""zamba2-7b  [hybrid] — Mamba2 backbone + weight-SHARED attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
[arXiv:2411.15242]

Zamba2's hallmark: the attention(+MLP) block's weights are SHARED across all
its applications, interleaved into the mamba2 stack.  We interleave one
shared-attn block after every 6 mamba blocks: 11 x (6 mamba + shared_attn)
+ 4 mamba = 81 layers.  The shared block's params are stored once.
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple((("mamba",) * 6 + ("shared_attn",)) * 11 + ("mamba",) * 4)
assert len(_PATTERN) == 81


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        d_ff=14336,
        vocab_size=32_000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        layer_pattern=_PATTERN,
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=3,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=32,
        layer_pattern=("mamba", "shared_attn", "mamba"),
        q_chunk=32,
        kv_chunk=32,
        dtype="float32",
        source="arXiv:2411.15242 (reduced)",
    )
