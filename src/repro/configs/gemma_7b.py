"""gemma-7b  [dense] — GeGLU, head_dim=256.

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.  [arXiv:2403.08295]
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256_000,
        mlp_act="gelu",
        tie_embeddings=True,
        source="arXiv:2403.08295",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=512,
        vocab_size=512,
        mlp_act="gelu",
        tie_embeddings=True,
        q_chunk=32,
        kv_chunk=32,
        dtype="float32",
        source="arXiv:2403.08295 (reduced)",
    )
