"""llama4-scout-17b-a16e  [moe]  — MoE 16 experts top-1, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Llama-4 uses iRoPE: 3 of every 4 layers use chunked local attention
(8192-token chunks), every 4th layer is global (NoPE).  That pattern is what
makes long_500k decode feasible (bounded KV on 3/4 of layers).
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple(("local", "local", "local", "attn") * 12)  # 48 layers


def full_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        n_experts=16,
        top_k=1,
        layer_pattern=_PATTERN,
        sliding_window=8192,
        rope_theta=500_000.0,
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        n_experts=4,
        top_k=1,
        layer_pattern=("local", "attn"),
        sliding_window=64,
        q_chunk=32,
        kv_chunk=32,
        moe_group=32,
        dtype="float32",
        source="hf:meta-llama/Llama-4-Scout-17B-16E (reduced)",
    )
