"""whisper-medium  [audio] — encoder-decoder, conv frontend (STUB).

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.  [arXiv:2212.04356]

Backbone only: the mel-spectrogram + conv feature extractor is a stub;
``input_specs()`` supplies precomputed frame embeddings of shape
(batch, enc_seq=1500, d_model) (whisper's 30 s @ 50 Hz post-conv frames).
Decoder self-attn + cross-attn to the encoder output.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,          # decoder layers
        n_enc_layers=24,      # encoder layers
        enc_seq=1500,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=51_865,
        mlp_act="gelu",
        source="arXiv:2212.04356",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=2,
        n_enc_layers=2,
        enc_seq=64,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        mlp_act="gelu",
        q_chunk=32,
        kv_chunk=32,
        dtype="float32",
        source="arXiv:2212.04356 (reduced)",
    )
