"""jit-able step functions: train_step (loss+grad+AdamW), prefill_step,
serve_step — plus ShapeDtypeStruct input_specs() for every assigned input
shape (the dry-run never allocates)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M
from repro.optim import adamw, cosine_schedule


def make_train_step(cfg: ModelConfig, optimizer=None, microbatches: int = 1):
    """AdamW train step; with microbatches > 1 the global batch is split
    and gradients accumulate in f32 across a lax.scan (standard grad
    accumulation — bounds activation memory at fixed global batch)."""
    opt = optimizer or adamw(cosine_schedule(3e-4, 100, 10_000),
                             weight_decay=0.1)

    def train_step(params, opt_state, batch):
        def loss_fn(p, b):
            return M.forward_train(p, cfg, b)

        if microbatches == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(leaf):
                b = leaf.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return leaf.reshape((microbatches, b // microbatches)
                                    + leaf.shape[1:])
            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32),
                  "acc": jnp.zeros((), jnp.float32)}

            def mb_step(carry, mb):
                gacc, macc = carry
                (_, mets), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                macc = {k: macc[k] + mets[k] for k in macc}
                return (gacc, macc), 0.0

            (grads, msum), _ = jax.lax.scan(mb_step, (g0, m0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {k: v / microbatches for k, v in msum.items()}
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, metrics

    return opt, train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token):
        return M.decode_step(params, cfg, cache, token)
    return serve_step


# ---------------------------------------------------------------------------
# ShapeDtypeStruct inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, logical):
    sharding = sh.named(logical, mesh) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh=None,
                kind: Optional[str] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the data batch of `shape`."""
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    i32, dt = jnp.int32, M._dt(cfg)
    batch_ok = mesh is None or _batch_shardable(mesh, b)
    b_ax = sh.BATCH if batch_ok else None

    out: Dict[str, Any] = {}
    if kind in ("train", "prefill"):
        s_text = s - (cfg.frontend_seq or 0)
        out["tokens"] = _sds((b, s_text), i32, mesh, (b_ax, None))
        if kind == "train":
            out["labels"] = _sds((b, s_text), i32, mesh, (b_ax, None))
        if cfg.frontend_seq:
            out["patches"] = _sds((b, cfg.frontend_seq, cfg.d_model), dt,
                                  mesh, (b_ax, None, None))
        if cfg.n_enc_layers:
            out["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), dt, mesh,
                                 (b_ax, None, None))
    else:  # decode
        out["token"] = _sds((b, 1), i32, mesh, (b_ax, None))
    return out


def _batch_shardable(mesh, b: int) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    return b % dp == 0


def cache_shape_specs(cfg: ModelConfig, shape: InputShape, mesh=None):
    """ShapeDtypeStructs for the decode cache at `shape` (via eval_shape —
    no allocation), with shardings attached."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    if mesh is None:
        return cache
    batch_ok = _batch_shardable(mesh, b)
    specs = M.cache_specs(cfg, cache, batch_shardable=batch_ok)
    return jax.tree.map(
        lambda leaf, spec: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=sh.named(spec, mesh)),
        cache, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_state(cfg: ModelConfig, mesh, with_opt: bool = True,
                   seed: int = 0):
    """(params, opt_state) ShapeDtypeStructs with shardings — dry-run
    inputs.  Uses eval_shape: no memory is allocated.

    Training keeps f32 master weights; serving (with_opt=False) models a
    bf16 deployment checkpoint."""
    key = jax.random.key(seed)
    p_shapes = jax.eval_shape(lambda k: M.init_model(k, cfg), key)
    spec_tree = M.param_specs(cfg, p_shapes)
    serve_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    params = jax.tree.map(
        lambda leaf, sp: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype if with_opt else serve_dt,
            sharding=sh.named(sp, mesh)),
        p_shapes, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    if not with_opt:
        return params, None
    opt_state = {
        "mu": jax.tree.map(lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.float32, sharding=l.sharding), params),
        "nu": jax.tree.map(lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.float32, sharding=l.sharding), params),
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=sh.named((), mesh)),
    }
    return params, opt_state
