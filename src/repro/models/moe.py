"""Top-1 (Switch-style) Mixture-of-Experts with grouped capacity routing.

Tokens are routed in groups of ``cfg.moe_group`` so the one-hot dispatch
einsum stays O(T * E * C_g * d) with C_g = ceil(cf * T_g / top_k... / E) —
the T5X/MaxText formulation that avoids a quadratic-in-T dispatch.
Experts shard over the `model` mesh axis (16 -> 1/chip, 128 -> 8/chip).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as sh
from repro.configs.base import ModelConfig


def init_moe(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    sc_in = 1.0 / math.sqrt(d)
    sc_out = 1.0 / math.sqrt(f)
    return {
        "router": sc_in * jax.random.normal(ks[0], (d, e), jnp.float32),
        "w_gate": sc_in * jax.random.normal(ks[1], (e, d, f), jnp.float32),
        "w_up": sc_in * jax.random.normal(ks[2], (e, d, f), jnp.float32),
        "w_down": sc_out * jax.random.normal(ks[3], (e, f, d), jnp.float32),
    }


def capacity(cfg: ModelConfig, group: int) -> int:
    return max(1, math.ceil(cfg.capacity_factor * group / cfg.n_experts))


def moe_block(params, x, cfg: ModelConfig):
    """x: [B, S, d] -> (y, aux_loss).  Top-1 capacity routing.

    Token groups are SEQUENCE chunks per batch element ([B, G, tg, d]) —
    the batch/seq dims never reshape-mix, so the sharded layout stays
    GSPMD-friendly: B on `batch`, G on `model` (seq-parallel residual),
    experts hop onto `model` at the dispatch all-to-all."""
    b, s, d = x.shape
    dt = x.dtype
    e = cfg.n_experts
    tg = min(cfg.moe_group, s)
    g = s // tg
    assert g * tg == s, (s, tg)
    c = capacity(cfg, tg)

    xg = x.reshape(b, g, tg, d)
    xg = sh.constrain(xg, (sh.BATCH, sh.MODEL, None, None))
    logits = jnp.einsum("bgtd,de->bgte", xg, params["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate = jnp.max(probs, axis=-1)                      # [b, g, t]
    expert = jnp.argmax(probs, axis=-1)
    onehot = jax.nn.one_hot(expert, e, dtype=jnp.float32)

    # Switch-transformer load-balance auxiliary loss.
    frac_tokens = jnp.mean(onehot, axis=2)              # [b, g, e]
    frac_probs = jnp.mean(probs, axis=2)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    # position of each token in its expert's queue; drop beyond capacity
    pos = jnp.cumsum(onehot, axis=2) * onehot - 1.0     # [b, g, t, e]
    keep = (pos >= 0) & (pos < c)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)
    dispatch = (onehot * keep)[..., None] * pos_oh      # [b, g, t, e, c]
    combine = (dispatch * gate[..., None, None]).astype(dt)
    dispatch = dispatch.astype(dt)

    xe = jnp.einsum("bgtec,bgtd->bgecd", dispatch, xg)
    xe = sh.constrain(xe, (sh.BATCH, None, sh.MODEL, None, None))
    ge = jnp.einsum("bgecd,edf->bgecf", xe, params["w_gate"].astype(dt))
    ue = jnp.einsum("bgecd,edf->bgecf", xe, params["w_up"].astype(dt))
    act = jax.nn.gelu(ge, approximate=True) if cfg.mlp_act == "gelu" \
        else jax.nn.silu(ge)
    ye = jnp.einsum("bgecf,efd->bgecd", act * ue,
                    params["w_down"].astype(dt))
    ye = sh.constrain(ye, (sh.BATCH, None, sh.MODEL, None, None))
    y = _combine(combine, ye, e)
    y = y.reshape(b, s, d)
    return sh.constrain(y, (sh.BATCH, sh.MODEL, None)), aux


def _combine(combine, ye, n_experts: int):
    """Un-dispatch: contract experts x capacity back to tokens.

    §Perf H2: the contraction over the expert-sharded dim produces
    partial sums; GSPMD lowers the plain constraint to all-reduce(full
    [b,g,t,d]) + slice, so when shapes allow we reduce-scatter onto the
    seq-group dim explicitly (mirrors layers.out_proj)."""
    import jax
    from jax.sharding import PartitionSpec as P

    b, g = combine.shape[0], combine.shape[1]
    mesh = sh.active_mesh()
    ok = (mesh is not None and "model" in mesh.axis_names
          and n_experts % sh.MODEL_PAR == 0)
    if ok:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("data", 1) * sizes.get("pod", 1)
        ok = g % sizes["model"] == 0 and b % dp == 0
    if ok:
        ba = sh.batch_mesh_axes(mesh)

        def f(cl, yl):
            part = jnp.einsum("bgtec,bgecd->bgtd", cl, yl)
            return jax.lax.psum_scatter(part, "model",
                                        scatter_dimension=1, tiled=True)
        return jax.shard_map(
            f, mesh=mesh,
            in_specs=(P(ba, None, None, "model", None),
                      P(ba, None, "model", None, None)),
            out_specs=P(ba, "model", None, None), check_vma=False)(
                combine, ye)
    y = jnp.einsum("bgtec,bgecd->bgtd", combine, ye)
    return sh.constrain(y, (sh.BATCH, sh.MODEL, None, None))
