from repro.models.model import init_model, forward_train, prefill, decode_step, init_cache  # noqa: F401
