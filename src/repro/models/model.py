"""Composable decoder/enc-dec model assembled from a layer-pattern plan.

A config's ``pattern`` (e.g. gemma3's 5x local + 1x global, zamba2's
6x mamba + shared-attn) is grouped into *runs* of consecutive identical
block types.  Each run's layer params are stacked on a leading dim and
executed with ``lax.scan`` (one compiled body per run — keeps the 512-device
SPMD compile tractable even for 81-layer stacks).  zamba2-style
``shared_attn`` blocks hold ONE param set reused at every application.

Shardings are derived from param *names + shapes* by ``param_specs`` —
a single source of truth used by smoke tests, the dry-run and the trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as sh
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

F32 = jnp.float32
LOSS_CHUNK = 512          # vocab-logit seq chunking (never materialize [B,S,V])


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Run:
    type: str          # attn | local | mamba | shared_attn
    count: int
    shared: bool


def build_plan(cfg: ModelConfig) -> Tuple[Run, ...]:
    runs: List[Run] = []
    for t in cfg.pattern:
        if t == "shared_attn":
            runs.append(Run("shared_attn", 1, True))
        elif runs and runs[-1].type == t and not runs[-1].shared:
            runs[-1] = Run(t, runs[-1].count + 1, False)
        else:
            runs.append(Run(t, 1, False))
    return tuple(runs)


def _vp(cfg: ModelConfig) -> int:
    return sh.pad_to(cfg.vocab_size, sh.MODEL_PAR)


def _dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn_layer(key, cfg: ModelConfig, *, moe: bool, cross: bool):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": jnp.zeros((d,)), "norm2": jnp.zeros((d,))}
    p["attn"] = L.init_attention(ks[0], cfg)
    if cross:
        p["normx"] = jnp.zeros((d,))
        p["cross"] = L.init_attention(ks[1], cfg)
    if moe:
        p["moe"] = MOE.init_moe(ks[2], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg)
    return p


def _init_mamba_layer(key, cfg: ModelConfig):
    return {"norm1": jnp.zeros((cfg.d_model,)),
            "mamba": SSM.init_mamba(key, cfg)}


def _stack(key, count: int, init_fn):
    keys = jax.random.split(key, count)
    ps = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ps)


def init_model(key, cfg: ModelConfig):
    """Returns the params pytree (f32 master weights)."""
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan) + 6)
    d = cfg.d_model
    vp = _vp(cfg)
    is_moe = cfg.n_experts > 0
    cross = cfg.n_enc_layers > 0

    params: Dict[str, Any] = {
        "embed": (d ** -0.5) * jax.random.normal(keys[0], (vp, d)),
        "final_norm": jnp.zeros((d,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (d ** -0.5) * jax.random.normal(keys[1], (d, vp))

    run_ps = []
    shared_done = False
    for i, run in enumerate(plan):
        k = keys[2 + i]
        if run.shared:
            if not shared_done:
                params["shared_attn"] = _init_attn_layer(
                    k, cfg, moe=False, cross=False)
                shared_done = True
            run_ps.append({})
        elif run.type == "mamba":
            run_ps.append(_stack(k, run.count,
                                 lambda kk: _init_mamba_layer(kk, cfg)))
        else:
            run_ps.append(_stack(
                k, run.count,
                lambda kk: _init_attn_layer(kk, cfg, moe=is_moe, cross=cross)))
    params["runs"] = tuple(run_ps)

    if cross:  # whisper encoder
        params["enc"] = {
            "runs": (_stack(keys[-3], cfg.n_enc_layers,
                            lambda kk: _init_attn_layer(kk, cfg, moe=False,
                                                        cross=False)),),
            "pos_embed": 0.02 * jax.random.normal(keys[-2],
                                                  (cfg.enc_seq, d)),
            "final_norm": jnp.zeros((d,)),
        }
    if cfg.frontend_seq:  # vlm projector (stub frontend -> backbone)
        params["proj"] = (d ** -0.5) * jax.random.normal(keys[-1], (d, d))
    return params


def param_specs(cfg: ModelConfig, params) -> Any:
    """Logical shardings from param names + shapes (single source of
    truth).  Works on real arrays or ShapeDtypeStructs.

    2D weight sharding: heads/experts/d_ff/vocab shard over `model`
    (tensor parallel) AND the d_model-ish dim shards over `fsdp` (= the
    data axis, ZeRO-3 style) so 100B+ params + AdamW state fit per chip.
    Gradients/optimizer state inherit the same specs."""
    _, ssm_h, _, _ = SSM.ssm_dims(cfg) if ("mamba" in cfg.pattern) \
        else (0, 1, 0, 0)
    ssm_ax = sh.MODEL if ssm_h % sh.MODEL_PAR == 0 else None

    def fs(dim: int):
        return sh.FSDP if dim % sh.MODEL_PAR == 0 else None

    def rule(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        stacked = "runs" in names and "pos_embed" not in names \
            and "final_norm" not in names
        nd = leaf.ndim
        shp = leaf.shape[1:] if stacked else leaf.shape
        base: Tuple[Optional[str], ...]
        if name == "embed":
            base = (sh.MODEL, fs(shp[1]))
        elif name == "lm_head":
            base = (fs(shp[0]), sh.MODEL)
        elif name == "wq":
            ax = sh.MODEL if sh.shard_heads(shp[1]) else None
            base = (fs(shp[0]), ax, None)
        elif name == "wo":
            ax = sh.MODEL if sh.shard_heads(shp[0]) else None
            base = (ax, None, fs(shp[2]))
        elif name in ("wk", "wv"):
            ax = sh.MODEL if sh.shard_heads(shp[1]) else None
            base = (fs(shp[0]), ax, None)
        elif name in ("w_gate", "w_up", "w_down"):
            if len(shp) == 3:           # moe expert weights [E, a, b]
                e_ax = sh.MODEL if shp[0] % sh.MODEL_PAR == 0 else None
                base = (e_ax, fs(shp[1]), None)
            elif name == "w_down":      # dense mlp [f, d]
                base = (sh.MODEL, fs(shp[1]))
            else:                       # dense mlp [d, f]
                base = (fs(shp[0]), sh.MODEL)
        elif name in ("w_z", "w_x", "w_bc", "w_dt"):
            base = (fs(shp[0]), ssm_ax)
        elif name in ("conv_x", "conv_bc"):
            base = (None, ssm_ax)
        elif name in ("dt_bias", "A_log", "D"):
            base = (ssm_ax,)
        elif name == "norm":            # mamba gated-norm scale [d_in]
            base = (ssm_ax,)
        elif name == "w_out":           # mamba out proj [d_in, d]
            base = (ssm_ax, fs(shp[1]))
        elif name == "proj":            # vlm projector [d, d]
            base = (fs(shp[0]), None)
        else:                           # norms, router, pos_embed...
            base = (None,) * len(shp)
        if stacked:
            base = (None,) + base
        assert len(base) == nd, (names, leaf.shape, base)
        return base

    return jax.tree_util.tree_map_with_path(rule, params)


# ---------------------------------------------------------------------------
# blocks (train / prefill)
# ---------------------------------------------------------------------------

def _attn_mlp_block(lp, x, cfg: ModelConfig, ltype: str, positions,
                    enc_out, nope_global: bool):
    h, kv = L.attention_block(
        lp["attn"], L.rms_norm(x, lp["norm1"], cfg.norm_eps), cfg, ltype,
        positions, nope=(nope_global and ltype == "attn"))
    x = x + h
    if "cross" in lp:
        h = L.cross_attention_block(
            lp["cross"], L.rms_norm(x, lp["normx"], cfg.norm_eps),
            enc_out, cfg)
        x = x + h
    y = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    if "moe" in lp:
        h, aux = MOE.moe_block(lp["moe"], y, cfg)
    else:
        h, aux = L.mlp_block(lp["mlp"], y, cfg), jnp.zeros((), F32)
    x = sh.constrain(x + h, (sh.BATCH, sh.MODEL, None))
    return x, kv, aux


def _run_forward(run: Run, rp, shared_p, x, cfg: ModelConfig, positions,
                 enc_out, collect_kv: bool):
    """Execute one run in train/prefill mode.  Returns (x, kv_stack, aux)."""
    nope_global = cfg.family == "moe"   # llama4 iRoPE: global layers NoPE

    if run.shared:
        x, kv, aux = _attn_mlp_block(shared_p, x, cfg, "attn", positions,
                                     enc_out, False)
        kv_out = jax.tree.map(lambda t: t[None], kv) if collect_kv else 0.0
        return x, kv_out, aux

    if run.type == "mamba":
        def body(carry, lp):
            h, st = SSM.mamba_block(
                lp["mamba"], L.rms_norm(carry, lp["norm1"], cfg.norm_eps),
                cfg)
            y = sh.constrain(carry + h, (sh.BATCH, sh.MODEL, None))
            return y, (st if collect_kv else 0.0)
        body = jax.checkpoint(body) if cfg.remat else body
        x, sts = lax.scan(body, x, rp)
        return x, sts, jnp.zeros((), F32)

    def body(carry, lp):
        y, kv, aux = _attn_mlp_block(lp, carry, cfg, run.type, positions,
                                     enc_out, nope_global)
        return y, ((kv if collect_kv else 0.0), aux)
    body = jax.checkpoint(body) if cfg.remat else body
    x, (kvs, auxs) = lax.scan(body, x, rp)
    return x, kvs, jnp.sum(auxs)


def _encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings [B, enc_seq, d]
    (bidirectional attention)."""
    enc = params["enc"]
    x = frames + enc["pos_embed"][None].astype(frames.dtype)
    ep = enc["runs"][0]

    def body(carry, lp):
        h = L.rms_norm(carry, lp["norm1"], cfg.norm_eps)
        dt = carry.dtype
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"].astype(dt))
        hq = q.shape[2]
        o = L.direct_attention(q, L._expand_kv(k, hq), L._expand_kv(v, hq),
                               None, dt)
        carry = carry + L.out_proj(lp["attn"], o, dt)
        y = L.rms_norm(carry, lp["norm2"], cfg.norm_eps)
        carry = carry + L.mlp_block(lp["mlp"], y, cfg)
        return carry, 0.0
    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(body, x, ep)
    return L.rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# embedding / logits / loss
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    e = params["embed"].astype(_dt(cfg))
    x = jnp.take(e, tokens, axis=0)
    # sequence-parallel residual stream (Megatron-SP): activations are
    # [batch-sharded, seq over `model`, full d_model] between layers.
    return sh.constrain(x, (sh.BATCH, sh.MODEL, None))


def _head_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T            # [d, Vp]
    return params["lm_head"]


def logits_fn(params, cfg: ModelConfig, hidden):
    w = _head_matrix(params, cfg).astype(hidden.dtype)
    logits = jnp.einsum("bsd,dv->bsv", hidden, w)
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:                # mask vocab padding
        neg = jnp.asarray(-1e30, logits.dtype)
        logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, neg)
    return logits


def chunked_lm_loss(params, cfg: ModelConfig, hidden, labels):
    """CE over vocab without materializing [B,S,V]: scan over seq chunks.
    labels: int32 [B,S], -1 = ignored position."""
    b, s, d = hidden.shape
    c = min(LOSS_CHUNK, s)
    nc = s // c
    assert nc * c == s, (s, c)
    h = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    lab = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)

    def body(carry, inp):
        hc, lc = inp                              # [B,c,d], [B,c]
        lg = logits_fn(params, cfg, hc).astype(F32)
        mask = (lc >= 0)
        li = jnp.maximum(lc, 0)
        logz = jax.scipy.special.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, li[..., None], axis=-1)[..., 0]
        loss_sum = jnp.sum((logz - ll) * mask)
        correct = jnp.sum((jnp.argmax(lg, -1) == li) * mask)
        tot, ls, cr = carry
        return (tot + jnp.sum(mask), ls + loss_sum, cr + correct), 0.0

    # never save per-chunk logits for backward — recompute (vocab-sharded
    # logits at f32 are the single biggest train buffer otherwise)
    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    (tot, loss_sum, correct), _ = lax.scan(
        body, (jnp.zeros((), F32),) * 3, (h, lab))
    return loss_sum / jnp.maximum(tot, 1.0), correct / jnp.maximum(tot, 1.0)


# ---------------------------------------------------------------------------
# public forward passes
# ---------------------------------------------------------------------------

def backbone(params, cfg: ModelConfig, x, positions, enc_out=None,
             collect_kv: bool = False):
    plan = build_plan(cfg)
    aux_total = jnp.zeros((), F32)
    kvs = []
    for i, run in enumerate(plan):
        x, kv, aux = _run_forward(run, params["runs"][i],
                                  params.get("shared_attn"), x, cfg,
                                  positions, enc_out, collect_kv)
        kvs.append(kv)
        aux_total = aux_total + aux
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, kvs, aux_total


def forward_train(params, cfg: ModelConfig, batch):
    """batch: tokens [B,St], labels [B,St] (-1 ignored), optional
    'patches' [B,P,d] (vlm) or 'frames' [B,enc,d] (audio)."""
    dt = _dt(cfg)
    tokens = batch["tokens"]
    labels = batch["labels"]
    x = embed_tokens(params, cfg, tokens)
    if cfg.frontend_seq:
        patches = batch["patches"].astype(dt)
        patches = jnp.einsum("bpd,de->bpe", patches, params["proj"].astype(dt))
        x = jnp.concatenate([patches, x], axis=1)
        pad = jnp.full(patches.shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = _encode(params, cfg, batch["frames"].astype(dt))
    positions = jnp.arange(x.shape[1])
    h, _, aux = backbone(params, cfg, x.astype(dt), positions, enc_out)
    loss, acc = chunked_lm_loss(params, cfg, h, labels)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux, "acc": acc}


# --- serving ---------------------------------------------------------------

def cache_capacity(cfg: ModelConfig, run: Run, seq_len: int) -> int:
    if run.type == "local":
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, enc_out=None,
               params=None):
    """Empty ring caches sized for `seq_len` context."""
    dt = _dt(cfg)
    plan = build_plan(cfg)
    hd = cfg.resolved_head_dim
    run_caches = []
    for run in plan:
        if run.type == "mamba":
            d_in, h, p, n = SSM.ssm_dims(cfg)
            run_caches.append({
                "state": jnp.zeros((run.count, batch, h, p, n), F32),
                "conv_x": jnp.zeros((run.count, batch, cfg.ssm_conv - 1,
                                     d_in), dt),
                "conv_bc": jnp.zeros((run.count, batch, cfg.ssm_conv - 1,
                                      2 * n), dt),
            })
        else:
            cap = cache_capacity(cfg, run, seq_len)
            c = {
                "k": jnp.zeros((run.count, batch, cap, cfg.n_kv_heads, hd),
                               dt),
                "v": jnp.zeros((run.count, batch, cap, cfg.n_kv_heads, hd),
                               dt),
                "slot_pos": jnp.full((run.count, cap), -1, jnp.int32),
            }
            if cfg.n_enc_layers:
                if params is not None and enc_out is not None:
                    rp = params["runs"][0]

                    def ckv(lp):
                        k = jnp.einsum("bsd,dhk->bshk", enc_out,
                                       lp["cross"]["wk"].astype(dt))
                        v = jnp.einsum("bsd,dhk->bshk", enc_out,
                                       lp["cross"]["wv"].astype(dt))
                        return k, v
                    c["ck"], c["cv"] = jax.vmap(ckv)(rp)
                else:
                    c["ck"] = jnp.zeros(
                        (run.count, batch, cfg.enc_seq, cfg.n_kv_heads, hd),
                        dt)
                    c["cv"] = jnp.zeros_like(c["ck"])
            run_caches.append(c)
    return {"pos": jnp.zeros((), jnp.int32), "runs": tuple(run_caches)}


def cache_specs(cfg: ModelConfig, cache, batch_shardable: bool = True) -> Any:
    """Logical shardings for a cache pytree: batch on data, cache-seq on
    model (flash-decode style sequence sharding — sidesteps kv-head
    divisibility).  When the batch can't shard (long_500k: B=1) the cache
    seq dim shards over EVERY mesh axis instead."""
    b_ax = sh.BATCH if batch_shardable else None
    s_ax = sh.MODEL if batch_shardable else sh.ALL
    # divisibility guards: MODEL axis = 16; ALL = up to 512 (2 pods)
    s_div = sh.MODEL_PAR if batch_shardable else 512

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        nd = leaf.ndim
        if name in ("k", "v", "ck", "cv"):
            s_ok = leaf.shape[2] % s_div == 0
            return (None, b_ax, s_ax if s_ok else None) + (None,) * (nd - 3)
        if name == "state":
            return (None, b_ax) + (None,) * (nd - 2)
        if name in ("conv_x", "conv_bc"):
            return (None, b_ax, None, None)
        return (None,) * nd
    return jax.tree_util.tree_map_with_path(spec_for, cache)


def prefill(params, cfg: ModelConfig, batch, max_len: Optional[int] = None):
    """Run the prompt, return (last_logits, cache).

    `max_len` sizes the global-attention caches (prompt + decode budget);
    defaults to the prompt length, in which case continued decoding rolls
    the ring (oldest tokens drop).  Local-window caches always ring over
    the window — that IS sliding-window semantics."""
    dt = _dt(cfg)
    tokens = batch["tokens"]
    x = embed_tokens(params, cfg, tokens)
    if cfg.frontend_seq:
        patches = batch["patches"].astype(dt)
        patches = jnp.einsum("bpd,de->bpe", patches, params["proj"].astype(dt))
        x = jnp.concatenate([patches, x], axis=1)
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = _encode(params, cfg, batch["frames"].astype(dt))
    s = x.shape[1]
    cache_len = max(max_len or s, s)
    positions = jnp.arange(s)
    h, kvs, _ = backbone(params, cfg, x.astype(dt), positions, enc_out,
                         collect_kv=True)
    last = logits_fn(params, cfg, h[:, -1:, :])[:, 0]
    cache = init_cache(cfg, x.shape[0], cache_len, enc_out=enc_out,
                       params=params)
    plan = build_plan(cfg)
    runs = list(cache["runs"])
    for i, run in enumerate(plan):
        rc = dict(runs[i])
        if run.type == "mamba":
            st, cx, cbc = kvs[i]
            rc["state"], rc["conv_x"], rc["conv_bc"] = st, cx, cbc
        else:
            k, v = kvs[i]                 # [L,B,S,Hkv,D]
            cap = cache_capacity(cfg, run, cache_len)
            if cap <= s:                  # ring holds the newest `cap`
                rc["k"] = k[:, :, -cap:]
                rc["v"] = v[:, :, -cap:]
                rc["slot_pos"] = jnp.broadcast_to(
                    jnp.arange(s - cap, s, dtype=jnp.int32)[None],
                    (run.count, cap))
            else:                         # headroom for decode
                rc["k"] = rc["k"].at[:, :, :s].set(k)
                rc["v"] = rc["v"].at[:, :, :s].set(v)
                sp = jnp.concatenate([
                    jnp.arange(s, dtype=jnp.int32),
                    jnp.full((cap - s,), -1, jnp.int32)])
                rc["slot_pos"] = jnp.broadcast_to(sp[None], (run.count, cap))
        runs[i] = rc
    return last, {"pos": jnp.asarray(s, jnp.int32), "runs": tuple(runs)}


def decode_step(params, cfg: ModelConfig, cache, token):
    """One decode step.  token: [B,1] int32.  Returns (logits, new_cache)."""
    pos = cache["pos"]
    x = embed_tokens(params, cfg, token)
    plan = build_plan(cfg)
    new_runs = []
    nope_global = cfg.family == "moe"
    cross = cfg.n_enc_layers > 0
    for i, run in enumerate(plan):
        rc = cache["runs"][i]
        rp = params["runs"][i]
        if run.shared:
            lc = {"k": rc["k"][0], "v": rc["v"][0],
                  "slot_pos": rc["slot_pos"][0]}
            x, nc = _decode_attn_layer_inner(
                params["shared_attn"], x, cfg, lc, pos, run, nope_global)
            out = dict(rc)
            out["k"] = rc["k"].at[0].set(nc["k"])
            out["v"] = rc["v"].at[0].set(nc["v"])
            out["slot_pos"] = rc["slot_pos"].at[0].set(nc["slot_pos"])
            new_runs.append(out)
        elif run.type == "mamba":
            def body(carry, inp):
                lp, st, cx, cbc = inp
                h, (st2, cx2, cbc2) = SSM.mamba_block(
                    lp["mamba"],
                    L.rms_norm(carry, lp["norm1"], cfg.norm_eps),
                    cfg, state=st, conv_x_state=cx, conv_bc_state=cbc,
                    decode=True)
                return carry + h, (st2, cx2, cbc2)
            x, (st2, cx2, cbc2) = lax.scan(
                body, x, (rp, rc["state"], rc["conv_x"], rc["conv_bc"]))
            new_runs.append({"state": st2, "conv_x": cx2, "conv_bc": cbc2})
        else:
            def body(carry, inp):
                if cross:
                    lp, k, v, sp, ck, cv = inp
                    lc = {"k": k, "v": v, "slot_pos": sp, "ck": ck, "cv": cv}
                else:
                    lp, k, v, sp = inp
                    lc = {"k": k, "v": v, "slot_pos": sp}
                y, nc = _decode_attn_layer_inner(lp, carry, cfg, lc, pos,
                                                 run, nope_global)
                return y, (nc["k"], nc["v"], nc["slot_pos"])
            xs = (rp, rc["k"], rc["v"], rc["slot_pos"])
            if cross:
                xs = xs + (rc["ck"], rc["cv"])
            x, (k2, v2, sp2) = lax.scan(body, x, xs)
            nc2 = dict(rc)
            nc2.update({"k": k2, "v": v2, "slot_pos": sp2})
            new_runs.append(nc2)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x)[:, 0]
    return logits, {"pos": pos + 1, "runs": tuple(new_runs)}


def _decode_attn_layer_inner(lp, x, cfg: ModelConfig, lc, pos, run: Run,
                             nope_global: bool):
    cap = lc["k"].shape[1]      # [B, cap, Hkv, D]
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    o, k_new, v_new = L.decode_attention(
        lp["attn"], h, cfg, lc["k"], lc["v"], lc["slot_pos"], pos,
        nope=(nope_global and run.type == "attn"),
        window=cfg.sliding_window if run.type == "local" else 0)
    x = x + o
    slot = jnp.mod(pos, cap)
    k2 = lax.dynamic_update_slice_in_dim(lc["k"], k_new[:, None], slot, 1)
    v2 = lax.dynamic_update_slice_in_dim(lc["v"], v_new[:, None], slot, 1)
    sp2 = lc["slot_pos"].at[slot].set(pos)
    if "cross" in lp:
        h = L.rms_norm(x, lp["normx"], cfg.norm_eps)
        x = x + _decode_cross(lp["cross"], h, lc["ck"], lc["cv"], cfg)
    y = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    if "moe" in lp:
        h, _ = MOE.moe_block(lp["moe"], y, cfg)
    else:
        h = L.mlp_block(lp["mlp"], y, cfg)
    x = x + h
    out = {"k": k2, "v": v2, "slot_pos": sp2}
    if "ck" in lc:
        out["ck"], out["cv"] = lc["ck"], lc["cv"]
    return x, out


def _decode_cross(cp, x, ck, cv, cfg: ModelConfig):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, cp["wq"].astype(dt))
    hq = q.shape[2]
    o = L.direct_attention(q, L._expand_kv(ck.astype(dt), hq),
                           L._expand_kv(cv.astype(dt), hq), None, dt)
    return L.out_proj(cp, o, dt)
