"""Core transformer layers: RMSNorm, RoPE, GQA attention (chunked
online-softmax for train/prefill, direct for decode), GeGLU/SwiGLU MLP.

All functions are pure; params are plain dicts, with a parallel dict of
*logical* PartitionSpec tuples (see repro.sharding).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as sh
from repro.configs.base import ModelConfig

Params = Dict[str, Any]
Specs = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


def dense_init(key, d_in: int, d_out_shape: Tuple[int, ...],
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    shape = (d_in,) + tuple(d_out_shape)
    return _normal(key, shape, scale)


# ---------------------------------------------------------------------------
# norm / rope
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                     / (head_dim // 2))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., None, :]                     # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq = sh.padded_heads(cfg.n_heads)
    hkv = cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {}
    p["wq"] = dense_init(ks[0], d, (hq, hd))
    # kv heads stay unpadded; replicated over model unless divisible.
    p["wk"] = dense_init(ks[1], d, (hkv, hd))
    p["wv"] = dense_init(ks[2], d, (hkv, hd))
    p["wo"] = dense_init(ks[3], hq * hd, (d,)).reshape(hq, hd, d)
    if hq != cfg.n_heads:
        # zero the padded heads end-to-end: exact numerics, pure flop padding.
        mask = (jnp.arange(hq) < cfg.n_heads).astype(p["wq"].dtype)
        p["wq"] = p["wq"] * mask[None, :, None]
        p["wo"] = p["wo"] * mask[:, None, None]
    return p


def _expand_kv(k, hq: int):
    """[B,S,Hkv,D] -> [B,S,Hq,D] by GQA group broadcast."""
    b, s, hkv, d = k.shape
    g = hq // hkv
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, g, d))
    return k.reshape(b, s, hkv * g, d)


def qkv(params, x, cfg: ModelConfig, positions, use_rope: bool):
    """Megatron-SP transition: x arrives sequence-sharded (seq on `model`);
    q/k/v leave HEAD-sharded with full sequence.  The explicit constraints
    make GSPMD do the seq-gather/head-scatter all-to-all instead of
    panicking into batch replication."""
    dt = x.dtype
    hq = params["wq"].shape[-2]
    hkv = params["wk"].shape[-2]
    q_ax = sh.MODEL if sh.shard_heads(hq) else None
    kv_ax = sh.MODEL if sh.shard_heads(hkv) else None
    # §Perf H2c: gather the sequence ONCE on the input (Megatron-SP "g")
    # instead of letting GSPMD gather q, k and v separately post-matmul.
    x = sh.constrain(x, (sh.BATCH, None, None))
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q = sh.constrain(q, (sh.BATCH, None, q_ax, None))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    k = sh.constrain(k, (sh.BATCH, None, kv_ax, None))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    v = sh.constrain(v, (sh.BATCH, None, kv_ax, None))
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _rs_eligible(mesh, contract_sharded: bool, s: int, b: int) -> bool:
    """Can we reduce-scatter the SP projection explicitly?"""
    if mesh is None or "model" not in mesh.axis_names:
        return False
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    return (contract_sharded and s > 1 and s % sizes["model"] == 0
            and b % dp == 0)


def out_proj(params, attn_out, dtype):
    """Head-sharded partials return to the seq-sharded residual stream.

    §Perf H2: GSPMD lowers the plain constraint to all-reduce(full
    [B,S,d]) + slice (2x the bytes of a reduce-scatter), so when shapes
    allow we emit the reduce-scatter explicitly via shard_map +
    psum_scatter over the seq dim (Megatron-SP's g-bar)."""
    wo = params["wo"].astype(dtype)
    mesh = sh.active_mesh()
    b, s = attn_out.shape[0], attn_out.shape[1]
    if _rs_eligible(mesh, sh.shard_heads(wo.shape[0]), s, b):
        ba = sh.batch_mesh_axes(mesh)
        from jax.sharding import PartitionSpec as P

        def f(xl, wl):
            part = jnp.einsum("bshk,hkd->bsd", xl, wl)
            return jax.lax.psum_scatter(part, "model",
                                        scatter_dimension=1, tiled=True)
        return jax.shard_map(
            f, mesh=mesh,
            in_specs=(P(ba, None, "model", None), P("model", None, None)),
            out_specs=P(ba, "model", None), check_vma=False)(attn_out, wo)
    out = jnp.einsum("bshk,hkd->bsd", attn_out, wo)
    return sh.constrain(out, (sh.BATCH, sh.MODEL, None))


def direct_attention(q, k, v, mask, dtype):
    """Materialized-scores attention. q:[B,Sq,H,D] k,v:[B,Sk,H,D];
    mask broadcastable to [B,H,Sq,Sk] (True = keep)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_causal_attention(q, k, v, *, q_chunk: int, window: int = 0):
    """Flash-style: scan over query chunks, never materializing [S,S].

    q, k, v: [B, S, H, D] (kv already GQA-expanded).  window=0 => global
    causal; window>0 => sliding-window causal (keys within (p-W, p]).
    For window>0 each q-chunk slices a fixed (W + q_chunk) key span —
    no wasted score FLOPs outside the band beyond chunk rounding.
    """
    b, s, h, d = q.shape
    dt = q.dtype
    nq = s // q_chunk
    assert nq * q_chunk == s, (s, q_chunk)
    scale = 1.0 / math.sqrt(d)

    if window:
        span = window + q_chunk

    def one_chunk(qi):
        q_start = qi * q_chunk
        qc = lax.dynamic_slice_in_dim(q, q_start, q_chunk, axis=1)
        qpos = q_start + jnp.arange(q_chunk)
        if window:
            k_start = jnp.maximum(q_start + q_chunk - span, 0)
            kc = lax.dynamic_slice_in_dim(k, k_start, min(span, s), axis=1)
            vc = lax.dynamic_slice_in_dim(v, k_start, min(span, s), axis=1)
            kpos = k_start + jnp.arange(kc.shape[1])
            keep = ((kpos[None, :] <= qpos[:, None])
                    & (kpos[None, :] > qpos[:, None] - window))
        else:
            kc, vc = k, v
            kpos = jnp.arange(s)
            keep = kpos[None, :] <= qpos[:, None]
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(jnp.float32)
        scores = jnp.where(keep[None, None], scores * scale, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, vc)

    # flash-attention-style remat: never save per-chunk scores/probs/masks
    # for backward — recompute them chunk-by-chunk (§Perf iteration 0).
    one_chunk = jax.checkpoint(
        one_chunk, policy=jax.checkpoint_policies.nothing_saveable)
    out = lax.map(one_chunk, jnp.arange(nq))          # [nq, B, qc, H, D]
    out = jnp.moveaxis(out, 0, 1)                     # [B, nq, qc, H, D]
    return out.reshape(b, s, h, d)


def attention_block(params, x, cfg: ModelConfig, layer_type: str, positions,
                    *, nope: bool = False,
                    enc_kv: Optional[Tuple[Any, Any]] = None):
    """Train/prefill attention ('attn' global or 'local' window).
    Returns (out, (k, v)) so prefill can build the cache."""
    use_rope = not nope
    q, k, v = qkv(params, x, cfg, positions, use_rope)
    hq = q.shape[2]
    ke, ve = _expand_kv(k, hq), _expand_kv(v, hq)
    window = cfg.sliding_window if layer_type == "local" else 0
    o = chunked_causal_attention(q, ke, ve, q_chunk=cfg.q_chunk, window=window)
    o = out_proj(params, o, x.dtype)
    return o, (k, v)


def cross_attention_block(params, x, enc_out, cfg: ModelConfig):
    """Whisper decoder cross-attention: full (non-causal) over encoder
    frames.  enc length is small (1500) so scores materialize."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    hq = q.shape[2]
    o = direct_attention(q, _expand_kv(k, hq), _expand_kv(v, hq), None, dt)
    return out_proj(params, o, dt)


def decode_attention(params, x, cfg: ModelConfig, k_cache, v_cache,
                     cache_positions, pos, *, nope: bool = False,
                     window: int = 0):
    """Single-token decode.  x: [B,1,d]; k_cache/v_cache: [B,S,Hkv,D]
    (seq dim model-sharded); cache_positions: [S] global positions held in
    each slot (-1 = empty); pos: scalar current position.

    Returns (out, new_k_slot, new_v_slot) — the caller owns the cache write.
    """
    dt = x.dtype
    q, k_new, v_new = qkv(params, x, cfg, jnp.full((1,), pos), not nope)
    # Attend over cache *plus* the new token.
    hq = q.shape[2]
    valid = (cache_positions >= 0) & (cache_positions <= pos)
    if window:
        valid = valid & (cache_positions > pos - window)
    scale = 1.0 / math.sqrt(q.shape[-1])
    ke = _expand_kv(k_cache.astype(dt), hq)           # [B,S,Hq,D]
    ve = _expand_kv(v_cache.astype(dt), hq)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, ke).astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    self_score = (jnp.einsum("bqhd,bqhd->bhq", q,
                             _expand_kv(k_new, hq)).astype(jnp.float32)
                  * scale)[..., None]                 # [B,H,1,1]
    scores = jnp.concatenate([scores, self_score], axis=-1)
    probs = jax.nn.softmax(scores, axis=-1).astype(dt)
    o_cache = jnp.einsum("bhqk,bkhd->bqhd", probs[..., :-1], ve)
    p_self = jnp.moveaxis(probs[..., -1], 1, 2)[..., None]      # [B,1,H,1]
    o = o_cache + p_self * _expand_kv(v_new, hq)
    o = out_proj(params, o, dt)
    return o, k_new[:, 0], v_new[:, 0]


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, (f,)),
        "w_up": dense_init(ks[1], d, (f,)),
        "w_down": dense_init(ks[2], f, (d,)),
    }


def mlp_block(params, x, cfg: ModelConfig):
    """SP transition mirror of qkv: seq-sharded in, d_ff-sharded inside,
    seq-sharded out (w_down partial-sums reduce-scatter back to seq)."""
    dt = x.dtype
    x = sh.constrain(x, (sh.BATCH, None, None))   # gather seq once (H2c)
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
    g = sh.constrain(g, (sh.BATCH, None, sh.MODEL))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    u = sh.constrain(u, (sh.BATCH, None, sh.MODEL))
    act = jax.nn.gelu(g, approximate=True) if cfg.mlp_act == "gelu" \
        else jax.nn.silu(g)
    h = act * u
    wd = params["w_down"].astype(dt)
    mesh = sh.active_mesh()
    b, s = h.shape[0], h.shape[1]
    if _rs_eligible(mesh, wd.shape[0] % sh.MODEL_PAR == 0, s, b):
        ba = sh.batch_mesh_axes(mesh)
        from jax.sharding import PartitionSpec as P

        def f(hl, wl):
            part = jnp.einsum("bsf,fd->bsd", hl, wl)
            return jax.lax.psum_scatter(part, "model",
                                        scatter_dimension=1, tiled=True)
        return jax.shard_map(
            f, mesh=mesh,
            in_specs=(P(ba, None, "model"), P("model", None)),
            out_specs=P(ba, "model", None), check_vma=False)(h, wd)
    out = jnp.einsum("bsf,fd->bsd", h, wd)
    return sh.constrain(out, (sh.BATCH, sh.MODEL, None))
