"""Mamba2 / SSD (state-space duality) block, chunked algorithm.

Train/prefill use the chunked SSD form (intra-chunk quadratic + inter-chunk
state recurrence via lax.scan); decode is the O(1) recurrent update.

TPU adaptations:
  * chunk length 256 keeps the intra-chunk [c, c] decay matmuls MXU-shaped;
  * projections are SEPARATE matmuls (x / BC / dt / z) instead of mamba's
    fused in_proj, so each output dim shards cleanly on the model axis with
    no shard-misaligned jnp.split (a fused projection's segment boundaries
    would cross GSPMD shard boundaries and force reshard collectives);
  * heads shard over `model` iff divisible by MODEL_PAR (zamba2: 112 heads
    -> 7/chip; mamba2-130m: 24 heads -> replicated, data-parallel carries it).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as sh
from repro.configs.base import ModelConfig

CHUNK = 256


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """PADDED dims: SSD heads pad up to a MODEL_PAR multiple (mamba2-130m:
    24 -> 32) so the SSD computation shards over `model` instead of
    replicating (§Perf H3: the idle-model-axis fix).  Dead heads carry
    zero weights end-to-end — numerically exact, pure flop padding."""
    p = cfg.ssm_head_dim
    h_valid = (cfg.ssm_expand * cfg.d_model) // p
    h = sh.padded_heads(h_valid)
    n = cfg.ssm_state
    return h * p, h, p, n


def ssm_valid_d_in(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba(key, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    d_in, h, p, n = ssm_dims(cfg)
    d_valid = ssm_valid_d_in(cfg)
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(d)
    chan_mask = (jnp.arange(d_in) < d_valid).astype(jnp.float32)
    head_mask = (jnp.arange(h) < d_valid // p).astype(jnp.float32)
    return {
        "w_z": sc * jax.random.normal(ks[0], (d, d_in)) * chan_mask[None],
        "w_x": sc * jax.random.normal(ks[1], (d, d_in)) * chan_mask[None],
        "w_bc": sc * jax.random.normal(ks[2], (d, 2 * n)),
        "w_dt": sc * jax.random.normal(ks[3], (d, h)) * head_mask[None],
        "conv_x": 0.1 * jax.random.normal(ks[4], (cfg.ssm_conv, d_in))
        * chan_mask[None],
        "conv_bc": 0.1 * jax.random.normal(ks[5], (cfg.ssm_conv, 2 * n)),
        "dt_bias": jnp.log(jnp.expm1(
            jax.random.uniform(ks[6], (h,), minval=1e-3, maxval=0.1))),
        "A_log": jnp.log(jax.random.uniform(ks[7], (h,), minval=1.0,
                                            maxval=16.0)),
        "D": head_mask,
        "norm": jnp.zeros((d_in,)),
        "w_out": (1.0 / math.sqrt(d_valid))
        * jax.random.normal(jax.random.fold_in(key, 99), (d_in, d))
        * chan_mask[:, None],
    }


def _causal_conv(x, w):
    """Depthwise causal conv, kernel K (small): x [B,S,C], w [K,C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
               for i in range(k))


def _segsum(a):
    """a: [..., c] -> [..., c, c]: out[i,j] = sum_{j<k<=i} a[k]; -inf j>i."""
    c = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, a_neg, bmat, cmat, init_state=None):
    """SSD scan.  x:[B,S,H,P] dt:[B,S,H] a_neg:[H] (negative),
    bmat,cmat:[B,S,N].  Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    c = min(CHUNK, s)
    nz = s // c
    assert nz * c == s, (s, c)
    f32 = jnp.float32

    da = dt.astype(f32) * a_neg.astype(f32)[None, None, :]      # [B,S,H] <=0
    xz = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, nz, c, h, p)
    da = da.reshape(b, nz, c, h)
    bz = bmat.astype(f32).reshape(b, nz, c, n)
    cz = cmat.astype(f32).reshape(b, nz, c, n)

    # --- intra-chunk (quadratic within chunk) ---
    seg = _segsum(jnp.moveaxis(da, -1, -2))          # [B,nz,H,c,c]
    decay = jnp.exp(seg)
    cb = jnp.einsum("bzin,bzjn->bzij", cz, bz)       # [B,nz,c,c]
    y_intra = jnp.einsum("bzij,bzhij,bzjhp->bzihp", cb, decay, xz)

    # --- chunk states ---
    cum = jnp.cumsum(da, axis=2)                     # [B,nz,c,H]
    total = cum[:, :, -1]                            # [B,nz,H]
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # [B,nz,c,H]
    states = jnp.einsum("bzch,bzchp,bzcn->bzhpn", decay_to_end, xz, bz)

    # --- inter-chunk recurrence (tiny state pass) ---
    h0 = (jnp.zeros((b, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st, tot = inp
        new = jnp.exp(tot)[:, :, None, None] * carry + st
        return new, carry                            # emit state *entering*

    final, entering = lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)          # [B,nz,H,P,N]

    y_inter = jnp.einsum("bzch,bzcn,bzhpn->bzchp", jnp.exp(cum), cz, entering)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def mamba_block(params, x, cfg: ModelConfig, state=None, conv_x_state=None,
                conv_bc_state=None, decode: bool = False):
    """x: [B,S,d].  Returns (y, (ssm_state, conv_x_state, conv_bc_state))."""
    d_in, h, p, n = ssm_dims(cfg)
    dt_ = x.dtype
    # SP transition: x arrives seq-sharded; projections leave CHANNEL-
    # sharded (over `model` when heads divide) with full sequence — the
    # SSD scan runs per head-shard over the whole sequence.
    # channel-sharded whenever the (padded) heads divide MODEL_PAR —
    # always true for h >= 16 after ssm_dims padding (§Perf H3)
    in_ax = sh.MODEL if h % sh.MODEL_PAR == 0 else None
    proj_spec = (sh.BATCH, None, in_ax)
    z = jnp.einsum("bsd,de->bse", x, params["w_z"].astype(dt_))
    z = sh.constrain(z, proj_spec)
    xs_raw = jnp.einsum("bsd,de->bse", x, params["w_x"].astype(dt_))
    xs_raw = sh.constrain(xs_raw, proj_spec)
    bc_raw = jnp.einsum("bsd,de->bse", x, params["w_bc"].astype(dt_))
    bc_raw = sh.constrain(bc_raw, proj_spec)
    dt_raw = jnp.einsum("bsd,de->bse", x, params["w_dt"].astype(dt_))
    dt_raw = sh.constrain(dt_raw, proj_spec)

    k = cfg.ssm_conv
    if decode:
        fx = jnp.concatenate([conv_x_state.astype(dt_), xs_raw], axis=1)
        fb = jnp.concatenate([conv_bc_state.astype(dt_), bc_raw], axis=1)
        xs_c = _causal_conv(fx, params["conv_x"])[:, -1:]
        bc_c = _causal_conv(fb, params["conv_bc"])[:, -1:]
        new_cx = fx[:, -(k - 1):]
        new_cbc = fb[:, -(k - 1):]
    else:
        xs_c = _causal_conv(xs_raw, params["conv_x"])
        bc_c = _causal_conv(bc_raw, params["conv_bc"])
        new_cx = xs_raw[:, -(k - 1):]
        new_cbc = bc_raw[:, -(k - 1):]
    xs_c = jax.nn.silu(xs_c)
    bc_c = jax.nn.silu(bc_c)

    bmat, cmat = jnp.split(bc_c, [n], axis=-1)
    bsz, s, _ = xs_c.shape
    xh = xs_c.reshape(bsz, s, h, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a_neg = -jnp.exp(params["A_log"])

    if decode:
        da = jnp.exp(dt[:, 0] * a_neg[None, :])          # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0],
                         xh[:, 0].astype(jnp.float32),
                         bmat[:, 0].astype(jnp.float32))
        new_state = da[:, :, None, None] * state.astype(jnp.float32) + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32),
                       new_state)
        y = y[:, None].astype(dt_)                       # [B,1,H,P]
        final = new_state
    else:
        y, final = ssd_chunked(xh, dt, a_neg, bmat, cmat, init_state=state)

    y = y + params["D"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(bsz, s, d_in)
    # gated RMSNorm over the VALID channels (dead padded channels are
    # exactly zero and must not dilute the variance)
    d_valid = ssm_valid_d_in(cfg)
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.sum(jnp.square(g), axis=-1, keepdims=True) / d_valid
    g = g * jax.lax.rsqrt(var + cfg.norm_eps) \
        * (1.0 + params["norm"].astype(jnp.float32))
    y = g.astype(dt_)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt_))
    return out, (final, new_cx, new_cbc)
