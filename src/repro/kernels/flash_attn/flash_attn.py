"""Pallas TPU kernel: causal flash attention with BLOCK-LEVEL causal skip.

This is the documented fix (EXPERIMENTS.md §Roofline) for the jnp chunked
attention's mask waste: the jnp path computes the full [q_chunk, S] score
rectangle and masks; this kernel's grid is (B*H, nq, nk) with
``pl.when(ki <= last_needed(qi))`` so strictly-above-diagonal key blocks
are never computed — ~2x fewer score FLOPs at long context, and the
online-softmax state lives in VMEM scratch across the innermost k loop.

Sliding-window (local) attention uses the same skip on BOTH sides of the
band, so a gemma3/llama4 local layer only touches window/k_block blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) \
    or getattr(pltpu, "CompilerParams")

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, q_block: int, k_block: int, window: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = qi * q_block
    k_start = ki * k_block
    # block-level causal band: this k block is needed iff it intersects
    # [q_start - window + 1, q_start + q_block - 1]
    needed = k_start <= q_start + q_block - 1
    if window:
        needed = jnp.logical_and(
            needed, k_start + k_block - 1 > q_start - window)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [qc, D]
        k = k_ref[0].astype(jnp.float32)              # [kc, D]
        v = v_ref[0].astype(jnp.float32)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jnp.dot(q, k.T) * scale                   # [qc, kc]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        keep = kpos <= qpos
        if window:
            keep = jnp.logical_and(keep, kpos > qpos - window)
        s = jnp.where(keep, s, NEG_INF)

        m_prev = m_ref[...]                           # [qc]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           q_block: int = 128, k_block: int = 128,
                           interpret: bool = True):
    """q,k,v: [B, H, S, D] -> [B, H, S, D].  causal must be True (the
    decoder case); window>0 adds sliding-window banding."""
    assert causal, "kernel is causal-only (decoder attention)"
    b, h, s, d = q.shape
    q_block = min(q_block, s)
    k_block = min(k_block, s)
    assert s % q_block == 0 and s % k_block == 0
    nq, nk = s // q_block, s // k_block
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)

    grid = (b * h, nq, nk)
    kern = functools.partial(_kernel, q_block=q_block, k_block=k_block,
                             window=window, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, k_block, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, k_block, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, d), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
