"""jit'd wrapper for the flash-attention kernel: layout plumbing
([B,S,H,D] model layout <-> [B,H,S,D] kernel layout), GQA expansion and
kernel/oracle dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.flash_attn import flash_attention_pallas
from repro.kernels.flash_attn.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "use_kernel",
                                             "interpret", "q_block",
                                             "k_block"))
def flash_attention(q, k, v, *, window: int = 0, use_kernel: bool = False,
                    interpret: bool = True, q_block: int = 128,
                    k_block: int = 128):
    """q: [B, S, Hq, D]; k,v: [B, S, Hkv, D] (GQA-expanded internally).
    Causal (+ optional sliding window).  Returns [B, S, Hq, D]."""
    hq, hkv = q.shape[2], k.shape[2]
    if hq != hkv:
        g = hq // hkv
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if use_kernel:
        out = flash_attention_pallas(qt, kt, vt, window=window,
                                     q_block=q_block, k_block=k_block,
                                     interpret=interpret)
    else:
        out = flash_attention_ref(qt, kt, vt, causal=True, window=window)
    return jnp.moveaxis(out, 1, 2)
