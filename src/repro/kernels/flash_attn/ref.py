"""Pure-jnp oracle: causal (optionally windowed) attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q,k,v: [B, H, S, D] -> [B, H, S, D]."""
    s = q.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    pos = jnp.arange(s)
    if causal:
        mask = pos[None, :] <= pos[:, None]
        if window:
            mask = mask & (pos[None, :] > pos[:, None] - window)
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
