"""jit'd public wrapper for the neighbor-aggregation kernels.

Handles B/D/K padding to the kernel tile shape, dtype plumbing, the
kernel / pure-jnp dispatch (the jnp path is what the 512-device dry-run
lowers; the Pallas path targets real TPUs and is validated in interpret
mode), and a custom VJP so BOTH training paths (full-graph GD and
mini-batch SGD) can differentiate through the kernel:

    d/dfeats = scatter-add of w[b,k] * g[b]   (segment-sum over idx)
    d/dw     = <g[b], feats[idx[b,k]]>

Padding is with zero-weight edges pointing at row 0, which the kernels
treat exactly (0 * row == 0).

Mesh-partitioned entry points (kernels/README.md "Sharding"):
``neighbor_agg_sharded`` runs the tiled kernel shard-locally over the
NODES mesh axis via shard_map — output rows / ids / weights sharded,
the feature table replicated so the software gather never crosses a
shard — with the custom VJP extended to psum-reduce ``dfeats`` across
shards; ``neighbor_agg_batch_sharded`` is the mini-batch twin over an
already-gathered fan-out level, where the flattened table itself is
row-sharded and NO collective is needed in either direction."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.neighbor_agg.neighbor_agg import (
    neighbor_agg_pallas, neighbor_agg_pallas_tiled)
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref


def _run_kernel(feats, idx, w, static):
    kernel, interpret, d_tile, b_tile, k_slab = static
    if kernel == "row":
        return neighbor_agg_pallas(feats, idx, w, d_tile=d_tile,
                                   interpret=interpret)
    return neighbor_agg_pallas_tiled(feats, idx, w, b_tile=b_tile,
                                     d_tile=d_tile, k_slab=k_slab,
                                     interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _agg(feats, idx, w, static):
    return _run_kernel(feats, idx, w, static)


def _agg_fwd(feats, idx, w, static):
    return _run_kernel(feats, idx, w, static), (feats, idx, w)


def _agg_bwd(static, res, g):
    # scan over the K axis so the backward's peak memory is O(N*D + B*D),
    # matching the forward kernel's no-[B,K,D]-blowup property instead of
    # materializing the full gather it exists to avoid
    feats, idx, w = res
    g32 = g.astype(jnp.float32)                       # [B, D]

    def body(dfeats, xs):
        idx_k, w_k = xs                               # [B], [B]
        rows = jnp.take(feats, idx_k, axis=0).astype(jnp.float32)
        dw_k = jnp.einsum("bd,bd->b", g32, rows)
        dfeats = dfeats.at[idx_k].add(
            w_k.astype(jnp.float32)[:, None] * g32)
        return dfeats, dw_k

    dfeats, dw_t = jax.lax.scan(
        body, jnp.zeros(feats.shape, jnp.float32), (idx.T, w.T))
    dfeats = dfeats.astype(feats.dtype)
    dw = dw_t.T.astype(w.dtype)
    didx = np.zeros(idx.shape, dtype=jax.dtypes.float0)
    return dfeats, didx, dw


_agg.defvjp(_agg_fwd, _agg_bwd)


# -- fused self-weight epilogue variant -------------------------------------
# out[b] = Σ_k w[b,k]·feats[idx[b,k]] + w_self[b]·self_rows[b] in ONE kernel
# (the epilogue folds into the accumulator init; see neighbor_agg.py)

def _run_kernel_fused(feats, idx, w, self_rows, w_self, static):
    _, interpret, d_tile, b_tile, k_slab = static
    return neighbor_agg_pallas_tiled(feats, idx, w, self_rows=self_rows,
                                     w_self=w_self, b_tile=b_tile,
                                     d_tile=d_tile, k_slab=k_slab,
                                     interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _agg_self(feats, idx, w, self_rows, w_self, static):
    return _run_kernel_fused(feats, idx, w, self_rows, w_self, static)


def _agg_self_fwd(feats, idx, w, self_rows, w_self, static):
    return (_run_kernel_fused(feats, idx, w, self_rows, w_self, static),
            (feats, idx, w, self_rows, w_self))


def _agg_self_bwd(static, res, g):
    feats, idx, w, self_rows, w_self = res
    dfeats, didx, dw = _agg_bwd(static, (feats, idx, w), g)
    g32 = g.astype(jnp.float32)
    dself = (w_self.astype(jnp.float32)[:, None] * g32
             ).astype(self_rows.dtype)
    dwself = jnp.einsum("bd,bd->b", g32, self_rows.astype(jnp.float32)
                        ).astype(w_self.dtype)
    return dfeats, didx, dw, dself, dwself


_agg_self.defvjp(_agg_self_fwd, _agg_self_bwd)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _tiled_call(feats, idx, w, self_rows, w_self, static):
    """Tile-pad + tiled-kernel dispatch, shared by the jit wrapper below
    and the shard-local bodies of the sharded entry points (the padding
    must be IDENTICAL in both so the sharded path stays bit-equal to the
    unsharded one on a 1-device mesh)."""
    _, _, d_tile, b_tile, k_slab = static
    b, k = idx.shape
    d = feats.shape[1]
    feats_p = _pad_to(feats, 1, d_tile)
    idx_p = _pad_to(_pad_to(idx, 0, b_tile), 1, k_slab)
    w_p = _pad_to(_pad_to(w, 0, b_tile), 1, k_slab)
    if self_rows is not None:
        self_p = _pad_to(_pad_to(self_rows, 0, b_tile), 1, d_tile)
        wself_p = _pad_to(w_self, 0, b_tile)
        out = _agg_self(feats_p, idx_p, w_p, self_p, wself_p, static)
    else:
        out = _agg(feats_p, idx_p, w_p, static)
    return out[:b, :d]


def _tiled_grads(static, feats, idx, w, self_rows, w_self, g):
    """Gradients of ``_tiled_call`` spelled out: the same pad ->
    ``_agg*_bwd`` -> slice composition jax's transpose machinery
    produces for the jit wrapper, so the shard-local backward of the
    sharded entry points is bit-identical to the unsharded kernel
    path's.  Returns ``(dfeats, dw, dself_rows, dw_self)`` (the last
    two ``None`` when not fused)."""
    _, _, d_tile, b_tile, k_slab = static
    b, k = idx.shape
    d = feats.shape[1]
    feats_p = _pad_to(feats, 1, d_tile)
    idx_p = _pad_to(_pad_to(idx, 0, b_tile), 1, k_slab)
    w_p = _pad_to(_pad_to(w, 0, b_tile), 1, k_slab)
    g_p = _pad_to(_pad_to(g, 0, b_tile), 1, d_tile)
    if self_rows is not None:
        self_p = _pad_to(_pad_to(self_rows, 0, b_tile), 1, d_tile)
        wself_p = _pad_to(w_self, 0, b_tile)
        df, _, dw, dself, dwself = _agg_self_bwd(
            static, (feats_p, idx_p, w_p, self_p, wself_p), g_p)
        return df[:, :d], dw[:b, :k], dself[:b, :d], dwself[:b]
    df, _, dw = _agg_bwd(static, (feats_p, idx_p, w_p), g_p)
    return df[:, :d], dw[:b, :k], None, None


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "kernel", "d_tile", "b_tile",
                                             "k_slab"))
def neighbor_agg(feats, idx, w, self_rows=None, w_self=None, *,
                 use_kernel: bool = False,
                 interpret: bool = True, kernel: str = "tiled",
                 d_tile: int = 128, b_tile: int = 8, k_slab: int = 4):
    """out[b] = Σ_k w[b,k] · feats[idx[b,k]]  [+ w_self[b] · self_rows[b]].

    feats [N, D]; idx [B, K] int32; w [B, K] (0 ⇒ padding edge);
    optional self_rows [B, D] + w_self [B] fuse the callers' self-loop
    epilogue into the tiled kernel's accumulator init (on the "row" /
    jnp dispatch paths the epilogue is applied outside the kernel).
    kernel: "tiled" (batch-tiled, double-buffered, production) | "row"
    (seed reference).  Differentiable wrt feats, w, self_rows and
    w_self in all dispatch modes.
    """
    assert kernel in ("row", "tiled"), kernel
    fused = self_rows is not None
    assert fused == (w_self is not None), \
        "self_rows and w_self must be passed together"
    if not use_kernel:
        out = neighbor_agg_ref(feats, idx, w)
        return out + w_self[:, None] * self_rows if fused else out
    b, k = idx.shape
    d = feats.shape[1]
    static = (kernel, interpret, d_tile, b_tile, k_slab)
    if kernel == "row":
        out = _agg(_pad_to(feats, 1, d_tile), idx, w, static)[:b, :d]
        return out + w_self[:, None] * self_rows if fused else out
    # padded rows carry w_self = 0, so the fused epilogue stays exact
    return _tiled_call(feats, idx, w, self_rows if fused else None,
                       w_self if fused else None, static)


# ---------------------------------------------------------------------------
# Mesh-partitioned entry points (shard_map over the NODES axis)
# ---------------------------------------------------------------------------
# The tiled kernel runs SHARD-LOCALLY: every shard owns a contiguous row
# block of the output / idx / w (+ self_rows / w_self) and gathers from a
# replicated feature table, so the forward needs no collective at all.
# Only the VJP's dfeats — a scatter-add into the REPLICATED table — must
# be psum-reduced across shards; dw / dself_rows / dw_self are row-local
# like their primals.  See kernels/README.md "Sharding".

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _agg_sharded(feats, idx, w, self_rows, w_self, sstatic):
    from repro import sharding as sh
    mesh, static = sstatic
    fused = self_rows is not None
    ins, row = sh.ell_agg_specs(mesh, fused)
    if fused:
        def local(f, i, ww, sr, ws):
            return _tiled_call(f, i, ww, sr, ws, static)
        return sh.shard_map(local, mesh, ins, row)(feats, idx, w,
                                                   self_rows, w_self)

    def local(f, i, ww):
        return _tiled_call(f, i, ww, None, None, static)
    return sh.shard_map(local, mesh, ins, row)(feats, idx, w)


def _agg_sharded_fwd(feats, idx, w, self_rows, w_self, sstatic):
    return (_agg_sharded(feats, idx, w, self_rows, w_self, sstatic),
            (feats, idx, w, self_rows, w_self))


def _agg_sharded_bwd(sstatic, res, g):
    from repro import sharding as sh
    mesh, static = sstatic
    feats, idx, w, self_rows, w_self = res
    fused = self_rows is not None
    ax = sh.nodes_axis(mesh)
    ins, row = sh.ell_agg_specs(mesh, fused)
    repl = ins[0]
    didx = np.zeros(idx.shape, dtype=jax.dtypes.float0)
    if fused:
        def local(f, i, ww, sr, ws, gg):
            df, dw, dsr, dws = _tiled_grads(static, f, i, ww, sr, ws, gg)
            return jax.lax.psum(df, ax), dw, dsr, dws

        row1 = ins[4]                       # the w_self spec: P(NODES)
        df, dw, dsr, dws = sh.shard_map(
            local, mesh, ins + (row,), (repl, row, row, row1)
        )(feats, idx, w, self_rows, w_self, g)
        return df, didx, dw, dsr, dws

    def local(f, i, ww, gg):
        df, dw, _, _ = _tiled_grads(static, f, i, ww, None, None, gg)
        return jax.lax.psum(df, ax), dw

    df, dw = sh.shard_map(local, mesh, ins + (row,),
                          (repl, row))(feats, idx, w, g)
    return df, didx, dw, None, None


_agg_sharded.defvjp(_agg_sharded_fwd, _agg_sharded_bwd)


def neighbor_agg_sharded(feats, idx, w, self_rows=None, w_self=None, *,
                         mesh=None, use_kernel: bool = True,
                         interpret: bool = True, d_tile: int = 128,
                         b_tile: int = 8, k_slab: int = 4):
    """``out[b] = Σ_k w[b,k]·feats[idx[b,k]] [+ w_self[b]·self_rows[b]]``
    partitioned over the NODES axis of ``mesh``: output rows / ``idx`` /
    ``w`` / ``self_rows`` / ``w_self`` shard their leading axis, the
    feature table replicates (the per-shard software gather is then
    purely local).  Rows pad internally up to a shard-count multiple
    with zero-weight edges, so any B is legal.

    On a 1-device mesh this is bit-identical to
    ``neighbor_agg(..., kernel="tiled")`` — forward AND gradients (the
    shard-local VJP mirrors the unsharded one exactly; the dfeats psum
    is an identity there).  ``mesh=None`` or ``use_kernel=False``
    dispatch straight to ``neighbor_agg`` (einsum path partitioning is
    GSPMD's job, not shard_map's)."""
    fused = self_rows is not None
    assert fused == (w_self is not None), \
        "self_rows and w_self must be passed together"
    if mesh is None or not use_kernel:
        return neighbor_agg(feats, idx, w, self_rows, w_self,
                            use_kernel=use_kernel, interpret=interpret,
                            kernel="tiled", d_tile=d_tile, b_tile=b_tile,
                            k_slab=k_slab)
    from repro import sharding as sh
    b = idx.shape[0]
    n_sh = sh.nodes_shards(mesh)
    idx = _pad_to(idx, 0, n_sh)
    w = _pad_to(w, 0, n_sh)
    if fused:
        self_rows = _pad_to(self_rows, 0, n_sh)
        w_self = _pad_to(w_self, 0, n_sh)
    static = ("tiled", interpret, d_tile, b_tile, k_slab)
    out = _agg_sharded(feats, idx, w, self_rows, w_self, (mesh, static))
    return out[:b] if out.shape[0] != b else out


# -- already-gathered (mini-batch fan-out) variant --------------------------
# The flattened [B*K, D] table is DERIVED from the row-sharded h_nb, so
# table rows live on the same shard as the output rows they feed: both
# the forward and the VJP are collective-free.

@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _agg_batch_sharded(w, h_nb, h_self, w_self, sstatic):
    from repro import sharding as sh
    mesh, static = sstatic
    fused = h_self is not None
    ax = sh.nodes_axis(mesh)
    from jax.sharding import PartitionSpec as P

    def row(nd):
        return P(*((ax,) + (None,) * (nd - 1)))

    def local(ww, nb, *rest):
        bl, k = ww.shape
        d = nb.shape[-1]
        table = nb.reshape(bl * k, d)
        ids = jnp.arange(bl * k, dtype=jnp.int32).reshape(bl, k)
        sr, ws = rest if rest else (None, None)
        return _tiled_call(table, ids, ww, sr, ws, static)

    ops = (w, h_nb) + ((h_self, w_self) if fused else ())
    ins = tuple(row(o.ndim) for o in ops)
    return sh.shard_map(local, mesh, ins, row(2))(*ops)


def _agg_batch_sharded_fwd(w, h_nb, h_self, w_self, sstatic):
    return (_agg_batch_sharded(w, h_nb, h_self, w_self, sstatic),
            (w, h_nb, h_self, w_self))


def _agg_batch_sharded_bwd(sstatic, res, g):
    from repro import sharding as sh
    mesh, static = sstatic
    w, h_nb, h_self, w_self = res
    fused = h_self is not None
    ax = sh.nodes_axis(mesh)
    from jax.sharding import PartitionSpec as P

    def row(nd):
        return P(*((ax,) + (None,) * (nd - 1)))

    def local(ww, nb, *rest):
        *sr_ws, gg = rest
        bl, k = ww.shape
        d = nb.shape[-1]
        table = nb.reshape(bl * k, d)
        ids = jnp.arange(bl * k, dtype=jnp.int32).reshape(bl, k)
        sr, ws = sr_ws if sr_ws else (None, None)
        df, dw, dsr, dws = _tiled_grads(static, table, ids, ww, sr, ws, gg)
        dnb = df.reshape(nb.shape)
        return (dw, dnb) + ((dsr, dws) if fused else ())

    ops = (w, h_nb) + ((h_self, w_self) if fused else ()) + (g,)
    ins = tuple(row(o.ndim) for o in ops)
    outs = (row(2), row(h_nb.ndim)) + ((row(2), row(1)) if fused else ())
    grads = sh.shard_map(local, mesh, ins, outs)(*ops)
    return tuple(grads) if fused else tuple(grads) + (None, None)


_agg_batch_sharded.defvjp(_agg_batch_sharded_fwd, _agg_batch_sharded_bwd)


# -- NODES-sharded feature table + degree-ordered hot cache -----------------
# The out-of-core entry point: no replicated [n, d] table anywhere.  Kept
# in its own module (featshard.py); re-exported here so callers keep one
# import surface for every neighbor-agg front-end.
from repro.kernels.neighbor_agg.featshard import (  # noqa: E402
    FeatShardPlan, build_featshard_plan, neighbor_agg_featshard,
    resolve_cache_rows)


def neighbor_agg_batch_sharded(w, h_nb, h_self=None, w_self=None, *, mesh,
                               interpret: bool = True, d_tile: int = 128,
                               b_tile: int = 8, k_slab: int = 4):
    """Tiled-kernel weighted sum over an ALREADY-GATHERED fan-out level
    (``h_nb [B, K, D]``, ``w [B, K]`` [+ fused ``h_self [B, D]`` /
    ``w_self [B]``]) with the target rows sharded over NODES: each shard
    flattens its local block to a ``[b_loc*K, D]`` table with identity
    ids and runs the same tiled kernel the unsharded mini-batch path
    uses — no collective in the forward or the VJP.  B must divide by
    the NODES shard count (the sharded mini-batch source rounds its
    batch up at bind, and fan-out products keep every level
    divisible)."""
    fused = h_self is not None
    assert fused == (w_self is not None), \
        "h_self and w_self must be passed together"
    from repro import sharding as sh
    n_sh = sh.nodes_shards(mesh)
    if w.shape[0] % n_sh:
        raise ValueError(
            f"neighbor_agg_batch_sharded: B={w.shape[0]} must be a "
            f"multiple of the {n_sh} NODES shards (the sharded sources "
            f"round b up to a mesh multiple at bind)")
    static = ("tiled", interpret, d_tile, b_tile, k_slab)
    return _agg_batch_sharded(w, h_nb, h_self, w_self, (mesh, static))
