"""jit'd public wrapper for the neighbor-aggregation kernel.

Handles D-padding to the VMEM lane tile, dtype plumbing, and the kernel /
pure-jnp dispatch (the jnp path is what the 512-device dry-run lowers; the
Pallas path targets real TPUs and is validated in interpret mode)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.neighbor_agg.neighbor_agg import neighbor_agg_pallas
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "d_tile"))
def neighbor_agg(feats, idx, w, *, use_kernel: bool = False,
                 interpret: bool = True, d_tile: int = 128):
    """out[b] = Σ_k w[b,k] · feats[idx[b,k]].

    feats [N, D]; idx [B, K] int32; w [B, K] (0 ⇒ padding edge).
    """
    if not use_kernel:
        return neighbor_agg_ref(feats, idx, w)
    n, d = feats.shape
    pad = (-d) % d_tile
    if pad:
        feats = jnp.pad(feats, ((0, 0), (0, pad)))
    out = neighbor_agg_pallas(feats, idx, w, d_tile=d_tile,
                              interpret=interpret)
    return out[:, :d] if pad else out
