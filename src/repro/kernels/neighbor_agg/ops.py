"""jit'd public wrapper for the neighbor-aggregation kernels.

Handles B/D/K padding to the kernel tile shape, dtype plumbing, the
kernel / pure-jnp dispatch (the jnp path is what the 512-device dry-run
lowers; the Pallas path targets real TPUs and is validated in interpret
mode), and a custom VJP so BOTH training paths (full-graph GD and
mini-batch SGD) can differentiate through the kernel:

    d/dfeats = scatter-add of w[b,k] * g[b]   (segment-sum over idx)
    d/dw     = <g[b], feats[idx[b,k]]>

Padding is with zero-weight edges pointing at row 0, which the kernels
treat exactly (0 * row == 0)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.neighbor_agg.neighbor_agg import (
    neighbor_agg_pallas, neighbor_agg_pallas_tiled)
from repro.kernels.neighbor_agg.ref import neighbor_agg_ref


def _run_kernel(feats, idx, w, static):
    kernel, interpret, d_tile, b_tile, k_slab = static
    if kernel == "row":
        return neighbor_agg_pallas(feats, idx, w, d_tile=d_tile,
                                   interpret=interpret)
    return neighbor_agg_pallas_tiled(feats, idx, w, b_tile=b_tile,
                                     d_tile=d_tile, k_slab=k_slab,
                                     interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _agg(feats, idx, w, static):
    return _run_kernel(feats, idx, w, static)


def _agg_fwd(feats, idx, w, static):
    return _run_kernel(feats, idx, w, static), (feats, idx, w)


def _agg_bwd(static, res, g):
    # scan over the K axis so the backward's peak memory is O(N*D + B*D),
    # matching the forward kernel's no-[B,K,D]-blowup property instead of
    # materializing the full gather it exists to avoid
    feats, idx, w = res
    g32 = g.astype(jnp.float32)                       # [B, D]

    def body(dfeats, xs):
        idx_k, w_k = xs                               # [B], [B]
        rows = jnp.take(feats, idx_k, axis=0).astype(jnp.float32)
        dw_k = jnp.einsum("bd,bd->b", g32, rows)
        dfeats = dfeats.at[idx_k].add(
            w_k.astype(jnp.float32)[:, None] * g32)
        return dfeats, dw_k

    dfeats, dw_t = jax.lax.scan(
        body, jnp.zeros(feats.shape, jnp.float32), (idx.T, w.T))
    dfeats = dfeats.astype(feats.dtype)
    dw = dw_t.T.astype(w.dtype)
    didx = np.zeros(idx.shape, dtype=jax.dtypes.float0)
    return dfeats, didx, dw


_agg.defvjp(_agg_fwd, _agg_bwd)


# -- fused self-weight epilogue variant -------------------------------------
# out[b] = Σ_k w[b,k]·feats[idx[b,k]] + w_self[b]·self_rows[b] in ONE kernel
# (the epilogue folds into the accumulator init; see neighbor_agg.py)

def _run_kernel_fused(feats, idx, w, self_rows, w_self, static):
    _, interpret, d_tile, b_tile, k_slab = static
    return neighbor_agg_pallas_tiled(feats, idx, w, self_rows=self_rows,
                                     w_self=w_self, b_tile=b_tile,
                                     d_tile=d_tile, k_slab=k_slab,
                                     interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _agg_self(feats, idx, w, self_rows, w_self, static):
    return _run_kernel_fused(feats, idx, w, self_rows, w_self, static)


def _agg_self_fwd(feats, idx, w, self_rows, w_self, static):
    return (_run_kernel_fused(feats, idx, w, self_rows, w_self, static),
            (feats, idx, w, self_rows, w_self))


def _agg_self_bwd(static, res, g):
    feats, idx, w, self_rows, w_self = res
    dfeats, didx, dw = _agg_bwd(static, (feats, idx, w), g)
    g32 = g.astype(jnp.float32)
    dself = (w_self.astype(jnp.float32)[:, None] * g32
             ).astype(self_rows.dtype)
    dwself = jnp.einsum("bd,bd->b", g32, self_rows.astype(jnp.float32)
                        ).astype(w_self.dtype)
    return dfeats, didx, dw, dself, dwself


_agg_self.defvjp(_agg_self_fwd, _agg_self_bwd)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret",
                                             "kernel", "d_tile", "b_tile",
                                             "k_slab"))
def neighbor_agg(feats, idx, w, self_rows=None, w_self=None, *,
                 use_kernel: bool = False,
                 interpret: bool = True, kernel: str = "tiled",
                 d_tile: int = 128, b_tile: int = 8, k_slab: int = 4):
    """out[b] = Σ_k w[b,k] · feats[idx[b,k]]  [+ w_self[b] · self_rows[b]].

    feats [N, D]; idx [B, K] int32; w [B, K] (0 ⇒ padding edge);
    optional self_rows [B, D] + w_self [B] fuse the callers' self-loop
    epilogue into the tiled kernel's accumulator init (on the "row" /
    jnp dispatch paths the epilogue is applied outside the kernel).
    kernel: "tiled" (batch-tiled, double-buffered, production) | "row"
    (seed reference).  Differentiable wrt feats, w, self_rows and
    w_self in all dispatch modes.
    """
    assert kernel in ("row", "tiled"), kernel
    fused = self_rows is not None
    assert fused == (w_self is not None), \
        "self_rows and w_self must be passed together"
    if not use_kernel:
        out = neighbor_agg_ref(feats, idx, w)
        return out + w_self[:, None] * self_rows if fused else out
    b, k = idx.shape
    d = feats.shape[1]
    feats_p = _pad_to(feats, 1, d_tile)
    static = (kernel, interpret, d_tile, b_tile, k_slab)
    if kernel == "row":
        out = _agg(feats_p, idx, w, static)[:b, :d]
        return out + w_self[:, None] * self_rows if fused else out
    idx_p = _pad_to(_pad_to(idx, 0, b_tile), 1, k_slab)
    w_p = _pad_to(_pad_to(w, 0, b_tile), 1, k_slab)
    if fused:
        # padded rows carry w_self = 0, so the fused epilogue stays exact
        self_p = _pad_to(_pad_to(self_rows, 0, b_tile), 1, d_tile)
        wself_p = _pad_to(w_self, 0, b_tile)
        out = _agg_self(feats_p, idx_p, w_p, self_p, wself_p, static)
    else:
        out = _agg(feats_p, idx_p, w_p, static)
    return out[:b, :d]
