"""Pure-jnp oracle for the neighbor-aggregation kernel.

out[b, :] = sum_k w[b, k] * feats[idx[b, k], :]

This is the message-passing hot-spot of both GNN training paradigms
(paper §1: mini-batch gathers; full-graph ELL aggregation)."""
from __future__ import annotations

import jax.numpy as jnp


def neighbor_agg_ref(feats, idx, w):
    """feats [N, D]; idx [B, K] int32; w [B, K] (0 = padding)."""
    gathered = jnp.take(feats, idx, axis=0)          # [B, K, D]
    return jnp.einsum("bk,bkd->bd", w.astype(jnp.float32),
                      gathered.astype(jnp.float32)).astype(feats.dtype)
