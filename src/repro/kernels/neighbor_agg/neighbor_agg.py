"""Pallas TPU kernels: weighted neighbor aggregation (software gather).

TPU adaptation of the GNN gather hot-spot (DESIGN.md §3): TPUs have no
hardware gather from HBM, so the neighbor ids are SCALAR-PREFETCHED and
drive per-row DMAs — each grid step moves exactly the feature rows it
needs HBM->VMEM and accumulates

    out[b, d_tile] += w[b, k] * feats[idx[b, k], d_tile]

into a revisited output block (grid order puts k innermost so the output
tile stays resident in VMEM across the K accumulation steps).

Two variants:

* `neighbor_agg_pallas` — the seed row kernel: one (1, d_tile) feature
  row per grid step, grid (B, D // d_tile, K).  Kept as the simple
  reference shape; every step pays one DMA issue + one weight-block load
  for a single accumulated row.

* `neighbor_agg_pallas_tiled` — batch-tiled AND pipelined: each grid
  step owns a (b_tile, d_tile) OUTPUT block and a K-slab of k_slab
  neighbors, grid (B // b_tile, D // d_tile, K // k_slab).  The
  b_tile * k_slab row DMAs of a slab are issued together (overlapped in
  hardware), the weight block (b_tile, k_slab) is loaded once per step
  instead of once per (row, k) pair, and the accumulator tile amortizes
  its init/flush over b_tile rows.  Zero-weight padding rows DMA like
  any other row but contribute exactly 0, so masked/padded inputs stay
  exact.

  Slab DMAs are DOUBLE-BUFFERED across the (innermost, sequential) K
  grid axis: the row buffer and its DMA semaphores carry a leading
  2-slot axis, slab ki lives in slot ki % 2, and while step ki
  accumulates its slab the DMAs for slab ki + 1 are already in flight
  into the other slot (flash_attn-style block pipelining).  Only the
  FIRST slab of each (bi, di) output tile is an exposed wait; every
  other slab's HBM latency hides behind the previous slab's FMAs.

  Optional fused epilogue: with `self_rows`/`w_self` the accumulator
  initializes to w_self[b] * self_rows[b, :] instead of zeros, so the
  callers' separate `w_self * h_self` elementwise pass (and its extra
  output-sized HBM round trip) disappears; a bias row would fold into
  the same init.

VMEM working set per tiled step:
rows (2, k_slab, b_tile, d_tile) + acc (b_tile, d_tile) + weights
(b_tile, k_slab) [+ self tile (b_tile, d_tile) + w_self (b_tile, 1)] —
keep b_tile * d_tile * (2 * k_slab + 2) * 4B under ~2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; the
# seed pinned the new name and broke on the baked-in jax (0.4.37)
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) \
    or getattr(pltpu, "CompilerParams")


# ---------------------------------------------------------------------------
# seed row kernel: one feature row tile per grid step
# ---------------------------------------------------------------------------

def _row_kernel(idx_ref, w_ref, feat_ref, out_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    weight = w_ref[0, 0].astype(jnp.float32)
    row = feat_ref[...].astype(jnp.float32)
    acc_ref[...] += weight * row

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def neighbor_agg_pallas(feats, idx, w, *, d_tile: int = 128,
                        interpret: bool = True):
    """feats [N, D]; idx [B, K] int32; w [B, K].  Returns [B, D].

    interpret=True on CPU (validation); on TPU pass interpret=False.
    D must be a multiple of d_tile (ops.py pads).
    """
    n, d = feats.shape
    b, k = idx.shape
    assert d % d_tile == 0, (d, d_tile)
    grid = (b, d // d_tile, k)

    flat_idx = idx.reshape(-1)               # scalar-prefetch operand

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # w[b, k] as a (1, 1) block
            pl.BlockSpec((1, 1), lambda bi, di, ki, idx_p: (bi, ki)),
            # the gathered feature row tile — index_map reads the
            # scalar-prefetched neighbor id
            pl.BlockSpec((1, d_tile),
                         lambda bi, di, ki, idx_p: (idx_p[bi * k + ki], di)),
        ],
        out_specs=pl.BlockSpec((1, d_tile),
                               lambda bi, di, ki, idx_p: (bi, di)),
        scratch_shapes=[pltpu.VMEM((1, d_tile), jnp.float32)],
    )
    fn = pl.pallas_call(
        _row_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), feats.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )
    return fn(flat_idx, w, feats)


# ---------------------------------------------------------------------------
# batch-tiled kernel: (b_tile, d_tile) output block, K-slab per step
# ---------------------------------------------------------------------------

def _make_tiled_kernel(b_tile: int, d_tile: int, k_slab: int, k_total: int,
                       fuse_self: bool):
    def kernel(idx_ref, w_ref, *refs):
        if fuse_self:
            wself_ref, self_ref, feat_ref, out_ref, rows_ref, acc_ref, \
                sems = refs
        else:
            feat_ref, out_ref, rows_ref, acc_ref, sems = refs
        bi = pl.program_id(0)
        di = pl.program_id(1)
        ki = pl.program_id(2)
        nk = pl.num_programs(2)

        def slab_copies(slab, slot):
            """The b_tile * k_slab row DMAs of K-slab `slab` into
            double-buffer slot `slot` (software gather: the
            scalar-prefetched ids address HBM rows directly)."""
            copies = []
            for j in range(k_slab):
                for i in range(b_tile):
                    nid = idx_ref[(bi * b_tile + i) * k_total
                                  + slab * k_slab + j]
                    copies.append(pltpu.make_async_copy(
                        feat_ref.at[nid, pl.ds(di * d_tile, d_tile)],
                        rows_ref.at[slot, j, i, :],
                        sems.at[slot, j, i]))
            return copies

        # two-slot rotation: slab s lives in slot s % 2.  The first slab
        # of each output tile is started here (exposed wait); every later
        # slab was prefetched by the PREVIOUS step and is already in
        # flight while that step accumulated.
        @pl.when(ki == 0)
        def _init():
            for c in slab_copies(0, 0):
                c.start()
            if fuse_self:    # fused epilogue: acc starts at w_self * self
                acc_ref[...] = wself_ref[...].astype(jnp.float32) \
                    * self_ref[...].astype(jnp.float32)
            else:
                acc_ref[...] = jnp.zeros_like(acc_ref)

        @pl.when(ki + 1 < nk)
        def _prefetch_next():
            for c in slab_copies(ki + 1, (ki + 1) % 2):
                c.start()

        for c in slab_copies(ki, ki % 2):
            c.wait()

        w_blk = w_ref[...].astype(jnp.float32)        # [b_tile, k_slab]
        slot = ki % 2
        for j in range(k_slab):
            acc_ref[...] += w_blk[:, j:j + 1] \
                * rows_ref[slot, j].astype(jnp.float32)

        @pl.when(ki == nk - 1)
        def _flush():
            out_ref[...] = acc_ref[...].astype(out_ref.dtype)

    return kernel


def neighbor_agg_pallas_tiled(feats, idx, w, *, self_rows=None, w_self=None,
                              b_tile: int = 8, d_tile: int = 128,
                              k_slab: int = 4, interpret: bool = True):
    """Batch-tiled, double-buffered software gather: feats [N, D];
    idx [B, K] int32; w [B, K] (0 ⇒ padding edge, exact).  Returns [B, D].

    With `self_rows` [B, D] + `w_self` [B] the epilogue
    out[b] += w_self[b] * self_rows[b] is fused into the accumulator
    init (both must be given together).

    B % b_tile == 0, D % d_tile == 0, K % k_slab == 0 (ops.py pads all
    three; padded rows/edges carry zero weight).
    """
    n, d = feats.shape
    b, k = idx.shape
    assert b % b_tile == 0, (b, b_tile)
    assert d % d_tile == 0, (d, d_tile)
    assert k % k_slab == 0, (k, k_slab)
    fuse_self = self_rows is not None
    assert fuse_self == (w_self is not None), \
        "self_rows and w_self must be passed together"
    grid = (b // b_tile, d // d_tile, k // k_slab)

    in_specs = [
        # the (b_tile, k_slab) weight block — ONE load per grid step
        pl.BlockSpec((b_tile, k_slab),
                     lambda bi, di, ki, idx_p: (bi, ki)),
    ]
    operands = [w]
    if fuse_self:
        in_specs += [
            # w_self as a (b_tile, 1) column, self rows as the same
            # (b_tile, d_tile) block shape as the output tile
            pl.BlockSpec((b_tile, 1), lambda bi, di, ki, idx_p: (bi, 0)),
            pl.BlockSpec((b_tile, d_tile),
                         lambda bi, di, ki, idx_p: (bi, di)),
        ]
        operands += [w_self.reshape(b, 1), self_rows]
    # full feature table stays in HBM; rows are DMA'd manually
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
    operands.append(feats)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b_tile, d_tile),
                               lambda bi, di, ki, idx_p: (bi, di)),
        scratch_shapes=[
            pltpu.VMEM((2, k_slab, b_tile, d_tile), feats.dtype),
            pltpu.VMEM((b_tile, d_tile), jnp.float32),
            pltpu.SemaphoreType.DMA((2, k_slab, b_tile)),
        ],
    )
    fn = pl.pallas_call(
        _make_tiled_kernel(b_tile, d_tile, k_slab, k, fuse_self),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), feats.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )
    return fn(idx.reshape(-1), *operands)
