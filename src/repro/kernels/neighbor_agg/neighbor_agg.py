"""Pallas TPU kernel: weighted neighbor aggregation (software gather).

TPU adaptation of the GNN gather hot-spot (DESIGN.md §3): TPUs have no
hardware gather from HBM, so the neighbor ids are SCALAR-PREFETCHED and
drive the feature BlockSpec's index_map — each grid step DMAs exactly one
needed feature row tile HBM->VMEM and accumulates

    out[b, d_tile] += w[b, k] * feats[idx[b, k], d_tile]

into a revisited output block (grid order puts k innermost so the output
tile stays resident in VMEM across the K accumulation steps).

Grid: (B, D // d_tile, K).  VMEM working set per step:
one feature row tile (d_tile) + one output tile (d_tile) + scalar weight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, feat_ref, out_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    weight = w_ref[0, 0].astype(jnp.float32)
    row = feat_ref[...].astype(jnp.float32)
    acc_ref[...] += weight * row

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def neighbor_agg_pallas(feats, idx, w, *, d_tile: int = 128,
                        interpret: bool = True):
    """feats [N, D]; idx [B, K] int32; w [B, K].  Returns [B, D].

    interpret=True on CPU (validation); on TPU pass interpret=False.
    D must be a multiple of d_tile (ops.py pads).
    """
    n, d = feats.shape
    b, k = idx.shape
    assert d % d_tile == 0, (d, d_tile)
    grid = (b, d // d_tile, k)

    flat_idx = idx.reshape(-1)               # scalar-prefetch operand

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # w[b, k] as a (1, 1) block
            pl.BlockSpec((1, 1), lambda bi, di, ki, idx_p: (bi, ki)),
            # the gathered feature row tile — index_map reads the
            # scalar-prefetched neighbor id
            pl.BlockSpec((1, d_tile),
                         lambda bi, di, ki, idx_p: (idx_p[bi * k + ki], di)),
        ],
        out_specs=pl.BlockSpec((1, d_tile),
                               lambda bi, di, ki, idx_p: (bi, di)),
        scratch_shapes=[pltpu.VMEM((1, d_tile), jnp.float32)],
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), feats.dtype),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )
    return fn(flat_idx, w, feats)
