"""NODES-sharded feature tables + degree-ordered hot cache.

Every earlier sharded entry point (``neighbor_agg_sharded``) replicates
the full ``[n, d]`` gather source on each device, so the largest graph is
capped by ONE device's memory.  This module drops that constraint:

- the table is row-sharded over the NODES mesh axis (owner shard of row
  ``i`` = ``i // (n_pad // S)``, the same contiguous-block layout
  ``ShardedFullGraphSource`` already uploads at rest);
- a **degree-ordered hot cache** — the top-C highest-degree rows — is
  replicated on every shard (power-law degree distributions make a small
  C catch most gather references);
- each shard's ELL gather is split at plan-build time into *hot/local
  hits* (phase 1: purely shard-local) and *cold remote misses* (phase 2):
  the misses are compacted into per-owner serve lists and move via ONE
  ``all_gather`` of only the miss set.  The serve gather depends only on
  the local table block, so XLA overlaps the collective with the phase-1
  Pallas aggregation; phase 2 then accumulates into the same output
  through the tiled kernel's fused self-weight epilogue (accumulator
  init = the phase-1 partial), i.e. both phases land in one VMEM tile
  accumulator.
- the custom VJP **scatter-adds** ``dfeats`` back to owner shards — a
  ``psum_scatter`` of the compacted ``[S·M, d]`` serve-grad buffer plus a
  ``psum`` of only the ``[C, d]`` hot rows — instead of psum-ing a
  replicated ``[n, d]`` table.

Per-device table memory drops from ``O(n·d)`` to
``O(n·d / S + C·d)`` (``table_bytes_per_device``); cross-shard traffic
per call is ``(S-1)·(M + C_max)`` rows (``remote_bytes_per_call``).

The plan is STATIC per (graph ELL, mesh, C): all index remapping happens
once at bind time on the host (``build_featshard_plan``); the op closes
over the resulting device arrays like the engine closes over its ELL
consts.  On a 1-device mesh every reference is hot or local and the miss
set is empty, so the op is bit-identical to the unsharded tiled kernel —
forward AND gradients (test-enforced, tests/test_featshard.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def resolve_cache_rows(cache_rows: Optional[int], n: int) -> int:
    """Hot-cache size C for ``GNNConfig.feat_cache_rows``: ``-1``/None →
    auto (n // 8, at least 1), ``0`` → no cache, else min(cache_rows, n).
    Only REAL rows (< n) are cacheable; padding rows have no edges."""
    if cache_rows is None or cache_rows < 0:
        return min(n, max(1, n // 8))
    return min(int(cache_rows), n)


# ---------------------------------------------------------------------------
# Host-side plan build (pure numpy — testable without a multi-device mesh)
# ---------------------------------------------------------------------------

def _plan_arrays(idx, w, degrees, n_shards: int, cache_rows: int) -> dict:
    """Classify every ELL entry against the (owner-map, hot-set) split and
    build the remapped per-shard index arrays.

    ``idx``/``w`` are the HOST ELL arrays already padded to an
    ``n_shards`` multiple of rows (zero-weight padding entries are
    treated as hits so they never generate serve traffic); ``degrees``
    ranks the n REAL rows for the hot set.
    """
    idx = np.asarray(idx)
    w = np.asarray(w)
    n_pad, K = idx.shape
    S = int(n_shards)
    if n_pad % S:
        raise ValueError(
            f"featshard plan: n_pad={n_pad} rows must divide the {S} "
            f"NODES shards (pad with zero-weight rows first)")
    n_loc = n_pad // S
    n = int(np.asarray(degrees).shape[0])
    C = resolve_cache_rows(cache_rows, n)

    # degree-ordered hot set (stable sort: deterministic under ties)
    order = np.argsort(-np.asarray(degrees, np.float64), kind="stable")
    hot_ids = order[:C].astype(np.int64)
    slot_of = np.full(n_pad, -1, np.int64)
    slot_of[hot_ids] = np.arange(C, dtype=np.int64)

    owner = np.arange(n_pad, dtype=np.int64) // n_loc     # owner map
    j = idx.astype(np.int64)
    nz = w != 0
    is_hot = slot_of[j] >= 0
    b_owner = owner[:, None]                              # shard of row b
    is_local = owner[j] == b_owner
    miss = nz & ~(is_hot | is_local)

    # phase 1: indices into concat(hot[C], local[n_loc]).  Every hot or
    # local reference keeps its faithful remap EVEN at zero weight, so
    # dw = <g, table[lidx]> matches the unsharded kernel bit-for-bit
    # wherever the row is reachable; only remote rows (misses, plus
    # zero-weight remote refs that must not join the serve set) point at
    # row 0 with zero effective weight.
    lidx_hot = np.where(is_hot, slot_of[j], C + (j - b_owner * n_loc))
    lidx_hot = np.where(is_hot | is_local, lidx_hot, 0).astype(np.int32)
    hot_mask = (~miss).astype(np.float32)

    # phase 2: compacted per-owner serve lists.  The gathered buffer is
    # laid out [S * M] identically on every shard (owner-major), so miss
    # indices owner*M + pos are shard-independent.
    j_miss = j[miss]
    miss_owner = owner[j_miss]
    serve_ids = [np.unique(j_miss[miss_owner == t]) for t in range(S)]
    M = int(max((len(s) for s in serve_ids), default=0))
    lidx_miss = np.zeros((n_pad, K), np.int32)
    serve_loc = np.zeros((S, max(M, 1)), np.int32)
    if M:
        pos_of = np.zeros(n_pad, np.int64)
        for t, ids in enumerate(serve_ids):               # disjoint by owner
            pos_of[ids] = np.arange(len(ids))
            serve_loc[t, : len(ids)] = ids - t * n_loc
        lidx_miss = np.where(miss, owner[j] * M + pos_of[j], 0
                             ).astype(np.int32)

    # hot-cache (re)build plumbing: which LOCAL rows each shard owns of
    # the hot set, and the static permutation that reassembles the
    # all_gathered owner-major parts back into slot order.
    C_max = 0
    hot_src_loc = hot_slot = hot_valid = hot_perm = None
    if C:
        hot_owner = owner[hot_ids]
        slots_by_t = [np.nonzero(hot_owner == t)[0] for t in range(S)]
        C_max = int(max(len(s) for s in slots_by_t))      # >= 1 when C > 0
        hot_src_loc = np.zeros((S, C_max), np.int32)
        hot_slot = np.zeros((S, C_max), np.int32)
        hot_valid = np.zeros((S, C_max), np.float32)
        hot_perm = np.zeros(C, np.int32)
        for t, slots in enumerate(slots_by_t):
            q = len(slots)
            hot_src_loc[t, :q] = hot_ids[slots] - t * n_loc
            hot_slot[t, :q] = slots
            hot_valid[t, :q] = 1.0
            hot_perm[slots] = t * C_max + np.arange(q)

    nz_total = int(nz.sum())
    n_miss = int(miss.sum())
    n_hot = int((nz & is_hot).sum())
    n_local = int((nz & is_local & ~is_hot).sum())
    stats = {
        "feat_table_shards": S,
        "feat_cache_rows": C,
        "feat_cache_hot_hits": n_hot,
        "feat_cache_local_hits": n_local,
        "feat_cache_misses": n_miss,
        "feat_cache_hit_rate": ((nz_total - n_miss) / nz_total
                                if nz_total else 1.0),
        # rows RECEIVED per device per aggregation call: the serve
        # all_gather ((S-1)·M remote rows) + the hot-cache fill
        # ((S-1)·C_max remote rows)
        "remote_rows_per_call": (S - 1) * (M + C_max),
    }
    return {
        "S": S, "n": n, "n_pad": n_pad, "n_loc": n_loc, "K": K,
        "C": C, "M": M, "C_max": C_max,
        "hot_ids": hot_ids,
        "lidx_hot": lidx_hot, "hot_mask": hot_mask,
        "lidx_miss": lidx_miss, "serve_loc": serve_loc,
        "hot_src_loc": hot_src_loc, "hot_slot": hot_slot,
        "hot_valid": hot_valid, "hot_perm": hot_perm,
        "stats": stats,
    }


# ---------------------------------------------------------------------------
# Device-resident plan
# ---------------------------------------------------------------------------

class FeatShardPlan:
    """Device-resident featshard plan for one (graph ELL, mesh, C).

    Deliberately a plain class with identity hash/eq: the plan rides jit
    STATIC arguments (``_eval_acc``) while its device index arrays are
    closed over by the op like the engine's ELL consts — both require a
    stable identity, which the sources' bind-time caches provide.
    """

    def __init__(self, mesh, host: dict):
        from repro import sharding as sh
        self.mesh = mesh
        for k in ("S", "n", "n_pad", "n_loc", "K", "C", "M", "C_max"):
            setattr(self, k, host[k])
        self.hot_ids = host["hot_ids"]
        self.stats = dict(host["stats"])
        rows2 = sh.named((sh.NODES, None), mesh)
        repl1 = sh.named((None,), mesh)

        def put(a):
            return jax.device_put(np.ascontiguousarray(a), rows2)

        self.lidx_hot = put(host["lidx_hot"])
        self.hot_mask = put(host["hot_mask"]) if self.M else None
        self.lidx_miss = put(host["lidx_miss"]) if self.M else None
        self.serve_loc = put(host["serve_loc"]) if self.M else None
        if self.C:
            self.hot_src_loc = put(host["hot_src_loc"])
            self.hot_slot = put(host["hot_slot"])
            self.hot_valid = put(host["hot_valid"])
            self.hot_perm = jax.device_put(host["hot_perm"], repl1)
        else:
            self.hot_src_loc = self.hot_slot = None
            self.hot_valid = self.hot_perm = None
        self._ops: dict = {}

    # -- bind-time accounting (ISSUE 8 acceptance: per-device bytes) ---
    def table_bytes_per_device(self, d: int, itemsize: int = 4) -> int:
        """Resident gather-source bytes per device: the local row block
        plus the replicated hot cache — n·d/S + C·d, NOT n·d."""
        return (self.n_loc + self.C) * d * itemsize

    def remote_bytes_per_call(self, d: int, itemsize: int = 4) -> int:
        """Bytes received per device per aggregation call (compacted
        serve all_gather + hot-cache fill)."""
        return self.stats["remote_rows_per_call"] * d * itemsize

    def _op(self, static, fused: bool):
        key = (static, fused)
        op = self._ops.get(key)
        if op is None:
            op = _make_op(self, static, fused)
            self._ops[key] = op
        return op


def build_featshard_plan(idx, w, degrees, mesh,
                         cache_rows: int = -1) -> FeatShardPlan:
    """Build the static featshard plan from HOST ELL arrays (already
    padded to a shard-count multiple of rows — ``ShardedFullGraphSource``
    pads at bind) and per-node degrees."""
    from repro import sharding as sh
    host = _plan_arrays(idx, w, degrees, sh.nodes_shards(mesh), cache_rows)
    return FeatShardPlan(mesh, host)


# ---------------------------------------------------------------------------
# The two-phase op (shard_map + manual custom VJP)
# ---------------------------------------------------------------------------

def _make_op(plan: FeatShardPlan, static, fused: bool):
    from repro import sharding as sh
    from repro.kernels.neighbor_agg.ops import _tiled_call, _tiled_grads

    mesh = plan.mesh
    ax = sh.nodes_axis(mesh)
    row2, row1, repl1 = P(ax, None), P(ax), P(None)
    has_miss = plan.M > 0
    has_hot = plan.C > 0
    C = plan.C

    aux = (plan.lidx_hot,)
    aux_specs = (row2,)
    if has_miss:
        aux += (plan.hot_mask, plan.lidx_miss, plan.serve_loc)
        aux_specs += (row2, row2, row2)
    if has_hot:
        aux += (plan.hot_src_loc, plan.hot_perm)
        aux_specs += (row2, repl1)
    # the VJP additionally needs the hot scatter-back maps
    baux = aux + ((plan.hot_slot, plan.hot_valid) if has_hot else ())
    baux_specs = aux_specs + ((row2, row2) if has_hot else ())

    def _unpack(rest, with_back):
        it = iter(rest)
        lh = next(it)
        hm = lm = sl = None
        if has_miss:
            hm, lm, sl = next(it), next(it), next(it)
        hsrc = hperm = hslot = hvalid = None
        if has_hot:
            hsrc, hperm = next(it), next(it)
            if with_back:
                hslot, hvalid = next(it), next(it)
        return lh, hm, lm, sl, hsrc, hperm, hslot, hvalid

    def _hot_table(f, hsrc, hperm):
        """Rebuild the [C, d] hot cache from the sharded table: each
        shard contributes its owned hot rows, one small all_gather of
        [S·C_max, d] owner-major parts, then the static slot permutation.
        Values refresh per call (layer tables change); the ID set is
        fixed per bind."""
        parts = jnp.take(f, hsrc[0], axis=0)              # [C_max, d]
        gathered = jax.lax.all_gather(parts, ax, tiled=True)
        return jnp.take(gathered, hperm, axis=0)          # [C, d]

    def _serve_gather(f, sl):
        """Compacted cold-miss move: each shard serves its [M] requested
        local rows, one all_gather -> the owner-major [S·M, d] buffer
        phase 2 gathers from."""
        serve = jnp.take(f, sl[0], axis=0)                # [M, d]
        return jax.lax.all_gather(serve, ax, tiled=True)  # [S·M, d]

    def _local_fwd(f, ww, sr, ws, lh, hm, lm, sl, hsrc, hperm):
        # the serve gather is issued FIRST and depends only on the local
        # block, so XLA overlaps the collective with the phase-1 Pallas
        # aggregation over hot/local rows
        gathered = _serve_gather(f, sl) if has_miss else None
        table1 = (jnp.concatenate([_hot_table(f, hsrc, hperm), f], 0)
                  if has_hot else f)
        w1 = ww * hm.astype(ww.dtype) if has_miss else ww
        out = _tiled_call(table1, lh, w1, sr, ws, static)
        if has_miss:
            # phase 2 accumulates the cold rows into the SAME output
            # through the fused epilogue (accumulator init = the phase-1
            # partial, w_self = 1)
            w2 = ww * (1.0 - hm).astype(ww.dtype)
            ones = jnp.ones((out.shape[0],), ww.dtype)
            out = _tiled_call(gathered, lm, w2, out, ones, static)
        return out

    def _fwd(feats, w, self_rows, w_self):
        ops_in = (feats, w) + ((self_rows, w_self) if fused else ())
        specs = (row2, row2) + ((row2, row1) if fused else ())

        def local(f, ww, *rest):
            rest = list(rest)
            sr = rest.pop(0) if fused else None
            ws = rest.pop(0) if fused else None
            lh, hm, lm, sl, hsrc, hperm, _, _ = _unpack(rest, False)
            return _local_fwd(f, ww, sr, ws, lh, hm, lm, sl, hsrc, hperm)

        return sh.shard_map(local, mesh, specs + aux_specs,
                            row2)(*ops_in, *aux)

    def _bwd(feats, w, self_rows, w_self, g):
        ops_in = ((feats, w) + ((self_rows, w_self) if fused else ())
                  + baux + (g,))
        specs = ((row2, row2) + ((row2, row1) if fused else ())
                 + baux_specs + (row2,))
        out_specs = (row2, row2) + ((row2, row1) if fused else ())

        def local(f, ww, *rest):
            rest = list(rest)
            sr = rest.pop(0) if fused else None
            ws = rest.pop(0) if fused else None
            gg = rest.pop()                  # g is the LAST operand
            lh, hm, lm, sl, hsrc, hperm, hslot, hvalid = \
                _unpack(rest, True)
            table1 = (jnp.concatenate([_hot_table(f, hsrc, hperm), f], 0)
                      if has_hot else f)
            w1 = ww * hm.astype(ww.dtype) if has_miss else ww
            # phase 2's cotangent into the phase-1 partial is exactly g
            # (w_self = 1), so phase 1 backpropagates g directly
            df1, dw1, dsr, dws = _tiled_grads(static, table1, lh, w1,
                                              sr, ws, gg)
            dloc = df1[C:] if has_hot else df1
            dw = dw1
            if has_miss:
                gathered = _serve_gather(f, sl)
                w2 = ww * (1.0 - hm).astype(ww.dtype)
                dgath, dw2, _, _ = _tiled_grads(static, gathered, lm, w2,
                                                None, None, gg)
                dw = jnp.where(hm > 0, dw1, dw2)
                # scatter-add the cold-row grads back to OWNER shards:
                # psum_scatter hands each shard its [M, d] serve slice
                # summed across requesters — never an [n, d] psum
                dserve = jax.lax.psum_scatter(dgath, ax,
                                              scatter_dimension=0,
                                              tiled=True)
                dloc = dloc.at[sl[0]].add(dserve.astype(dloc.dtype))
            if has_hot:
                # only the C hot rows cross every shard
                dhot = jax.lax.psum(df1[:C], ax)
                back = (jnp.take(dhot, hslot[0], axis=0)
                        * hvalid[0][:, None])
                dloc = dloc.at[hsrc[0]].add(back.astype(dloc.dtype))
            return (dloc, dw) + ((dsr, dws) if fused else ())

        return sh.shard_map(local, mesh, specs, out_specs)(*ops_in)

    @jax.custom_vjp
    def op(feats, w, self_rows, w_self):
        return _fwd(feats, w, self_rows, w_self)

    def op_fwd(feats, w, self_rows, w_self):
        return _fwd(feats, w, self_rows, w_self), (feats, w, self_rows,
                                                   w_self)

    def op_bwd(res, g):
        grads = _bwd(*res, g)
        return tuple(grads) if fused else tuple(grads) + (None, None)

    op.defvjp(op_fwd, op_bwd)
    return op


def neighbor_agg_featshard(feats, w, plan: FeatShardPlan, self_rows=None,
                           w_self=None, *, interpret: bool = True,
                           d_tile: int = 128, b_tile: int = 8,
                           k_slab: int = 4):
    """``out[b] = Σ_k w[b,k]·feats[idx[b,k]] [+ w_self[b]·self_rows[b]]``
    with the SOURCE TABLE row-sharded over the plan's NODES mesh (no
    replicated [n, d] copy anywhere): phase-1 tiled Pallas aggregation
    over hot-cache/local hits overlapped with the compacted cold-miss
    ``all_gather``, phase-2 accumulation of the cold rows into the same
    output, and a scatter-add (not psum-of-replicated) VJP.

    ``feats`` [n_pad, d] and optional ``self_rows`` [n_pad, d] are
    NODES-row-sharded; ``w`` [n_pad, K] / ``w_self`` [n_pad] row-sharded
    with the SAME zero pattern the plan was built from (the plan encodes
    the index remap, so ``ell_idx`` itself is not an operand).  Output
    rows stay NODES-sharded — layer l's output table feeds layer l+1
    without a relayout.  On a 1-device mesh this is bit-identical to
    ``neighbor_agg(..., kernel="tiled")``, forward and gradients."""
    fused = self_rows is not None
    assert fused == (w_self is not None), \
        "self_rows and w_self must be passed together"
    if feats.shape[0] != plan.n_pad or w.shape != (plan.n_pad, plan.K):
        raise ValueError(
            f"neighbor_agg_featshard: operands (feats {feats.shape}, "
            f"w {w.shape}) do not match the plan "
            f"(n_pad={plan.n_pad}, K={plan.K}) — rebuild the plan for "
            f"this ELL/mesh")
    static = ("tiled", bool(interpret), int(d_tile), int(b_tile),
              int(k_slab))
    return plan._op(static, fused)(feats, w, self_rows, w_self)
