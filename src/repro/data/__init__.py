from repro.data.synth import make_sbm_graph, PRESETS, make_preset, token_batches  # noqa: F401
