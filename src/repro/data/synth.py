"""Synthetic data: SBM graphs standing in for the paper's OGB datasets
(data gate — see DESIGN.md), plus a toy token pipeline for the LM archs.

Features are class-conditioned Gaussians (matches the paper's assumption
that labels are sampled conditioned on features, §2).  Presets mirror each
dataset's *regime* (classes, homophily, average degree), not its size.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.graph import Graph


def make_sbm_graph(n: int, n_classes: int, avg_degree: float,
                   homophily: float = 0.8, feat_dim: int = 32,
                   feat_scale: float = 1.0, train_frac: float = 0.5,
                   val_frac: float = 0.1, seed: int = 0,
                   power_law: bool = False) -> Graph:
    """Stochastic block model, undirected, no self-edges in A (the
    normalized adjacency adds self-loops per the paper)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n).astype(np.int32)

    # per-node degree budget
    if power_law:
        deg = np.minimum(
            (avg_degree / 2.0) * (rng.pareto(2.0, n) + 1.0), n / 4
        ).astype(np.int64)
    else:
        deg = rng.poisson(avg_degree, n).astype(np.int64)
    deg = np.maximum(deg, 1)

    # sample edges: for each node pick targets, homophilous w.p. h
    srcs, dsts = [], []
    by_class = [np.nonzero(labels == c)[0] for c in range(n_classes)]
    for u in range(n):
        k = max(int(deg[u] // 2), 1)
        same = rng.random(k) < homophily
        pool_same = by_class[labels[u]]
        t_same = rng.choice(pool_same, size=int(same.sum()))
        t_rand = rng.integers(0, n, size=int((~same).sum()))
        t = np.concatenate([t_same, t_rand])
        t = t[t != u]
        srcs.append(np.full(len(t), u))
        dsts.append(t)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    # symmetrize + dedupe
    a = np.concatenate([src, dst])
    b = np.concatenate([dst, src])
    eid = a.astype(np.int64) * n + b
    eid = np.unique(eid)
    a = (eid // n).astype(np.int32)
    b = (eid % n).astype(np.int32)

    order = np.argsort(a, kind="stable")
    a, b = a[order], b[order]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, a + 1, 1)
    indptr = np.cumsum(indptr)
    indices = b

    # class-conditioned Gaussian features
    mus = rng.normal(0, feat_scale, (n_classes, feat_dim)).astype(np.float32)
    feats = (mus[labels]
             + rng.normal(0, 1.0, (n, feat_dim)).astype(np.float32))

    perm = rng.permutation(n)
    n_tr = int(train_frac * n)
    n_va = int(val_frac * n)
    train_mask = np.zeros(n, bool)
    val_mask = np.zeros(n, bool)
    test_mask = np.zeros(n, bool)
    train_mask[perm[:n_tr]] = True
    val_mask[perm[n_tr:n_tr + n_va]] = True
    test_mask[perm[n_tr + n_va:]] = True
    return Graph(n=n, indptr=indptr, indices=indices, feats=feats,
                 labels=labels, train_mask=train_mask, val_mask=val_mask,
                 test_mask=test_mask)


# Presets echo each OGB/reddit dataset's regime (avg degree, classes,
# homophily) at CPU-tractable size — see DESIGN.md "data gate".
PRESETS: Dict[str, dict] = {
    # reddit: dense social graph, avg deg ~492 -> scaled to 60
    "reddit-like": dict(n=3000, n_classes=16, avg_degree=60.0,
                        homophily=0.75, feat_dim=64),
    # ogbn-arxiv: citation graph, avg deg ~13.7
    "arxiv-like": dict(n=3000, n_classes=12, avg_degree=14.0,
                       homophily=0.65, feat_dim=64),
    # ogbn-products: co-purchase, avg deg ~50.5
    "products-like": dict(n=4000, n_classes=16, avg_degree=50.0,
                          homophily=0.8, feat_dim=64),
    # ogbn-papers100M: citation, avg deg ~29, many classes, power-law
    "papers-like": dict(n=5000, n_classes=24, avg_degree=29.0,
                        homophily=0.6, feat_dim=64, power_law=True),
}


def make_preset(name: str, seed: int = 0, **overrides) -> Graph:
    kw = dict(PRESETS[name])
    kw.update(overrides)
    return make_sbm_graph(seed=seed, **kw)


# ---------------------------------------------------------------------------
# toy token pipeline for the LM archs (examples / smoke training)
# ---------------------------------------------------------------------------

def token_batches(vocab: int, batch: int, seq: int, seed: int = 0,
                  n_batches: Optional[int] = None) -> Iterator[dict]:
    """Markov-chain synthetic tokens (learnable structure, not uniform
    noise) — enough for loss-goes-down end-to-end runs."""
    rng = np.random.default_rng(seed)
    v_eff = min(vocab, 256)
    trans = rng.dirichlet(np.ones(v_eff) * 0.1, size=v_eff)
    cum = np.cumsum(trans, axis=1)
    i = 0
    while n_batches is None or i < n_batches:
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, v_eff, batch)
        u = rng.random((batch, seq))
        for t in range(seq):
            toks[:, t + 1] = (u[:, t:t + 1]
                              < cum[toks[:, t]]).argmax(1)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        i += 1
