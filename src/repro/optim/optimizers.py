"""Minimal optax-free optimizers: AdamW, SGD(+momentum), schedules,
global-norm clipping.  States are pytrees mirroring params so they inherit
the same shardings."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) ->
    #                                            (new_params, new_state)


def constant_schedule(lr: float) -> Callable[[Any], Any]:
    return lambda step: jnp.asarray(lr, F32)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.0) -> Callable[[Any], Any]:
    def fn(step):
        step = step.astype(F32) if hasattr(step, "astype") else float(step)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), gn


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = sched(step)
        t = step.astype(F32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, mu, nu):
            g = g.astype(F32)
            mu2 = b1 * mu + (1 - b1) * g
            nu2 = b2 * nu + (1 - b2) * jnp.square(g)
            mh = mu2 / c1
            nh = nu2 / c2
            delta = mh / (jnp.sqrt(nh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(F32)
            p2 = p.astype(F32) - lr_t * delta
            return p2.astype(p.dtype), mu2, nu2

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        treedef = jax.tree.structure(params)
        flat = treedef.flatten_up_to(out)
        new_p = treedef.unflatten([o[0] for o in flat])
        new_mu = treedef.unflatten([o[1] for o in flat])
        new_nu = treedef.unflatten([o[2] for o in flat])
        return new_p, {"mu": new_mu, "nu": new_nu, "step": step}

    return Optimizer(init, update)


def sgd(lr: Callable | float, momentum: float = 0.0) -> Optimizer:
    """Plain (S)GD — used for the paper's full-graph GD and mini-batch SGD
    experiments (the paper's optimizer; App. N)."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum:
            return {"vel": jax.tree.map(
                lambda p: jnp.zeros(p.shape, F32), params),
                "step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        if momentum:
            vel = jax.tree.map(
                lambda v, g: momentum * v + g.astype(F32),
                state["vel"], grads)
            new_p = jax.tree.map(
                lambda p, v: (p.astype(F32) - lr_t * v).astype(p.dtype),
                params, vel)
            return new_p, {"vel": vel, "step": step}
        new_p = jax.tree.map(
            lambda p, g: (p.astype(F32) - lr_t * g.astype(F32)).astype(
                p.dtype), params, grads)
        return new_p, {"step": step}

    return Optimizer(init, update)
