from repro.optim.optimizers import (  # noqa: F401
    adamw, sgd, Optimizer, cosine_schedule, constant_schedule,
    clip_by_global_norm)
