"""The paper's evaluation metrics (§5.1): iteration-to-loss,
iteration-to-accuracy, time-to-accuracy, throughput — and the cost model
used for the Fig.-1-style bandwidth thought experiment."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional


@dataclasses.dataclass
class History:
    """Per-iteration training record."""
    losses: List[float] = dataclasses.field(default_factory=list)
    full_losses: List[float] = dataclasses.field(default_factory=list)
    full_loss_iters: List[int] = dataclasses.field(default_factory=list)
    val_accs: List[float] = dataclasses.field(default_factory=list)
    val_acc_iters: List[int] = dataclasses.field(default_factory=list)
    times: List[float] = dataclasses.field(default_factory=list)
    nodes_processed: List[int] = dataclasses.field(default_factory=list)
    #: 1-based iterations whose step produced a non-finite loss/grad and
    #: was skipped/rolled back by the engine's BadStepPolicy
    bad_steps: List[int] = dataclasses.field(default_factory=list)
    #: run-level scalar counters (not per-iteration): the feature-shard /
    #: hot-cache accounting (hit rate, remote-gather bytes, per-device
    #: table bytes) lands here at train end (HistoryCallback)
    counters: dict = dataclasses.field(default_factory=dict)
    _t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    # -- checkpoint serialization (engine exact-resume) ----------------
    def to_dict(self) -> dict:
        """JSON-serializable snapshot.  Python floats round-trip exactly
        through ``json`` (repr-based), so a resumed run's restored
        History compares bit-for-bit with the uninterrupted one —
        except ``times``, which restart from the resume wall-clock."""
        return {f.name: (dict(v) if isinstance(v, dict) else list(v))
                for f in dataclasses.fields(self)
                if not f.name.startswith("_")
                for v in (getattr(self, f.name),)}

    @classmethod
    def from_dict(cls, d: dict) -> "History":
        h = cls()
        for f in dataclasses.fields(cls):
            if not f.name.startswith("_") and f.name in d:
                v = d[f.name]
                setattr(h, f.name, dict(v) if isinstance(v, dict)
                        else list(v))
        return h

    def record(self, loss: float, val_acc: Optional[float] = None,
               nodes: int = 0):
        self.losses.append(float(loss))
        if val_acc is not None:
            self.val_accs.append(float(val_acc))
            # evals happen only every eval_every iterations: remember the
            # 1-based iteration of each one (like full_loss_iters) so the
            # *_to_accuracy helpers report true iteration numbers
            self.val_acc_iters.append(len(self.losses))
        self.times.append(time.perf_counter() - (self._t0 or 0.0))
        self.nodes_processed.append(nodes)


def iteration_to_loss(hist: History, target: float) -> Optional[int]:
    """# iterations until train loss <= target (None = never)."""
    for i, l in enumerate(hist.losses):
        if l <= target:
            return i + 1
    return None


def iteration_to_full_loss(hist: History, target: float) -> Optional[int]:
    """# iterations until the FULL training objective <= target — the
    paper's iteration-to-loss (per-batch losses are too noisy; first
    crossings of a noisy series bias small batches early)."""
    for it, l in zip(hist.full_loss_iters, hist.full_losses):
        if l <= target:
            return it
    return None


def iteration_to_accuracy(hist: History, target: float) -> Optional[int]:
    """# iterations until val accuracy >= target (None = never).

    ``val_accs`` is recorded only every ``eval_every`` iterations, so the
    list index is NOT the iteration number — use the recorded
    ``val_acc_iters`` (falling back to index+1 for hand-built Histories
    without them, where the lists are the same length)."""
    iters = (hist.val_acc_iters
             if len(hist.val_acc_iters) == len(hist.val_accs)
             else range(1, len(hist.val_accs) + 1))
    for it, a in zip(iters, hist.val_accs):
        if a >= target:
            return it
    return None


def time_to_accuracy(hist: History, target: float) -> Optional[float]:
    it = iteration_to_accuracy(hist, target)
    if it is None:
        return None
    # wall time at the iteration that crossed the target (times has one
    # entry per training iteration, 1-based `it`)
    return hist.times[min(it, len(hist.times)) - 1]


def throughput_nodes_per_sec(hist: History) -> float:
    """Training throughput = target nodes processed / wall time (§5.4)."""
    total = sum(hist.nodes_processed)
    t = hist.times[-1] if hist.times else 0.0
    return total / t if t > 0 else 0.0


def simulated_time_to_acc(iter_to_acc: int, nodes_per_iter: float,
                          bandwidth_nodes_per_sec: float) -> float:
    """§5.1's non-rigorous derivation: time = iters * nodes / bandwidth.
    Used for the Fig. 1 hardware-(in)dependence demonstration without
    real heterogeneous hardware."""
    return iter_to_acc * nodes_per_iter / bandwidth_nodes_per_sec
