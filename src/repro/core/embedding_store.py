"""Write-safe cached per-layer embedding tables: versioned snapshots,
a write-ahead update log, and a budgeted refresh scheduler.

``EmbeddingStore`` materializes every layer's [n, d_l] table once (the
layer-wise pass from ``core.inference``) and then keeps them fresh under
point updates without full recomputes.  Invalidation follows the
FORWARD influence cone: a change to node u's layer-(l-1) embedding can
only move layer-l rows that aggregate u — u itself (self-loop) plus the
rows whose ELL lists reference u (a reverse index built from the
nonzero-weight ELL entries).  ``refresh()`` therefore re-embeds, per
layer, ``dirty_rows ∪ changed ∪ referencing(changed)`` and carries that
set forward as the next layer's ``changed`` — the k-hop frontier of the
marked nodes, NOT the whole graph.  Re-embeds go through the same
module-level compiled chunk step as the build pass (same chunk padding,
same static config), so no new compilation is paid at update time.

Concurrency model (PR 10, the serving twin of PR 6's fault tolerance):

- **Versioned snapshots** — the serving state is an immutable
  ``TableSnapshot`` (layer tables + a host copy of the final logits +
  a monotonically increasing version), swapped atomically under
  ``_mu``.  ``refresh()``/``build()`` construct the NEXT version off
  the serving path (jax ``.at[].set`` never mutates the published
  arrays) and only publish on success: a crash or injected fault
  mid-refresh (failpoints ``store.mid_layer_refresh``,
  ``store.before_swap``) discards the partial version and queries keep
  answering from the old one — no reader can ever observe a torn or
  half-refreshed table.
- **Write-ahead update log** — ``update_features`` / ``add_edges`` /
  ``mark_dirty`` append to the WAL instead of mutating build state, so
  writers never race an in-flight refresh.  Records are applied (graph
  feats / CSR / ELL rows / dirty masks) under ``_refresh_mu``:
  opportunistically right away when no refresh is running (which keeps
  the PR-7 eager semantics for single-threaded users), otherwise at
  the next refresh's drain.  Dirty masks are cleared only AFTER a
  successful publish, so an aborted refresh loses no invalidation.
- **Refresh scheduler** — ``start_scheduler()`` runs a daemon thread
  that coalesces pending updates and re-embeds on a budget:
  ``refresh_every_updates`` (count trigger), ``refresh_budget_ms``
  (pacing: at most one scheduled refresh per budget window) and
  ``max_staleness_s`` (proactive refresh at half the SLO bound).
  Transient refresh faults (``faults.TransientRefreshFault``) are
  retried with exponential backoff; any other incremental failure
  degrades to ONE full ``build()`` before surfacing fatal
  (``refresh_with_recovery`` — also used synchronously by
  ``GNNServer`` when the staleness SLO forces a refresh on the batcher
  thread).  ``SimulatedCrash`` is a BaseException and always sails
  through, exactly like a real process death.

Two update channels (tests/test_embedding_store.py validates both
against a from-scratch store on the updated graph):

- ``update_features(nodes, feats)`` / ``mark_dirty(nodes)`` — layer-0
  inputs changed; the ELL is untouched.
- ``add_edges(src, dst)`` — structural: the CSR is rebuilt, and because
  ã weights depend on BOTH endpoint degrees, the re-derived ELL rows are
  the endpoints PLUS every current neighbor of an endpoint (their edge
  weights to the endpoint changed).  Those rows are marked dirty at
  every layer.

``core.serving`` answers classification queries from the current
snapshot via ``predict_meta()`` (host-side argmax over the snapshot's
cached numpy copy — no per-query-shape retracing, no refresh on the
read path); ``predict()`` keeps the PR-7 auto-refresh convenience for
direct single-threaded use.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core import faults
from repro.core.engine import _static_cfg
from repro.core.graph import Graph, to_ell
from repro.core.inference import (InferenceRun, _chunk_apply, _pre_source,
                                  layerwise_layers)


@dataclasses.dataclass(frozen=True)
class TableSnapshot:
    """One immutable, consistent serving state.

    ``layers[l]`` is the layer-(l+1) table the build/refresh that
    published this version produced; ``final_np`` is the host copy of
    ``layers[-1]`` (the logits) every query slices.  Snapshots are
    never mutated after publish — a refresh builds a NEW snapshot and
    swaps the store's pointer, so any reader holding this object keeps
    a consistent view forever."""

    version: int
    layers: Tuple[jax.Array, ...]
    final_np: np.ndarray
    published_t: float          # time.monotonic() at publish


class EmbeddingStore:
    """Per-layer embedding cache over a (mutable) graph.

    ``max_deg=None`` keeps full neighborhoods (inference default);
    ``mesh`` routes chunk aggregation through the NODES-sharded kernel
    path (requires ``cfg.use_agg_kernel``).

    Lock order (never taken in reverse): ``_refresh_mu`` (serializes
    build/refresh/WAL-apply — the only paths that mutate build state)
    then ``_mu`` (short critical sections: WAL append/drain, dirty
    masks, snapshot pointer, counters)."""

    def __init__(self, params, cfg: GNNConfig, graph: Graph, *,
                 chunk_size: int = 1024, max_deg: Optional[int] = None,
                 mesh=None, prefetch: bool = True):
        self.params = params
        self.cfg = cfg
        self._scfg = _static_cfg(cfg)
        self.graph = graph
        self.max_deg = max_deg
        self.mesh = mesh
        self.prefetch = prefetch
        self.chunk_size = max(1, min(int(chunk_size), graph.n))
        self.idx, self.w, self.w_self = to_ell(graph, max_deg=max_deg)
        self.K = self.idx.shape[1]
        self._h0 = jnp.asarray(graph.feats)
        # feats_layout="sharded": the full build runs the NODES-sharded
        # featshard pass (no replicated table); incremental refreshes
        # keep the chunked path — dirty frontiers are tiny row sets
        self.feats_plan = None
        if (cfg.feats_layout == "sharded" and cfg.use_agg_kernel
                and mesh is not None
                and cfg.model in ("gcn", "graphsage")):
            from repro import sharding as sh
            from repro.kernels.neighbor_agg.ops import build_featshard_plan
            pad = (-graph.n) % sh.nodes_shards(mesh)
            idx_p = (np.pad(self.idx, ((0, pad), (0, 0)))
                     if pad else self.idx)
            w_p = np.pad(self.w, ((0, pad), (0, 0))) if pad else self.w
            self.feats_plan = build_featshard_plan(
                idx_p, w_p, graph.degrees, mesh,
                cache_rows=cfg.feat_cache_rows)
        self.build_stats: Optional[Dict] = None
        self._dirty_in = np.zeros(graph.n, bool)    # layer-0 inputs moved
        self._dirty_row = np.zeros(graph.n, bool)   # ELL row re-derived
        self._rev = None                            # lazy reverse index
        # -- write-safe serving state --------------------------------
        self._mu = threading.RLock()
        self._refresh_mu = threading.RLock()
        self._snap: Optional[TableSnapshot] = None
        self._version = 0
        self._wal: List[Tuple] = []       # (kind, payload..., t) records
        self._applied_unpublished = 0     # drained but not yet published
        self._dirty_since: Optional[float] = None
        self._counters = {"refreshes": 0, "builds": 0,
                          "transient_retries": 0, "degraded_builds": 0,
                          "sched_refreshes": 0}
        self._last_refresh_error: Optional[BaseException] = None
        self._sched_stop = threading.Event()
        self._sched_cfg: Optional[Dict] = None
        self._sched_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    # snapshot access
    # ------------------------------------------------------------------
    @property
    def layers(self) -> Optional[List[jax.Array]]:
        """The current snapshot's layer tables (a fresh list; the
        underlying arrays are immutable).  ``None`` before the first
        build — PR-7 compatible read surface."""
        with self._mu:
            snap = self._snap
        return None if snap is None else list(snap.layers)

    def snapshot(self) -> Optional[TableSnapshot]:
        """The last consistently published ``TableSnapshot`` (or None
        before the first build).  Safe to hold across updates — it is
        never mutated."""
        with self._mu:
            return self._snap

    @property
    def version(self) -> int:
        """Version of the serving snapshot (0 before the first build)."""
        with self._mu:
            return self._version

    # ------------------------------------------------------------------
    # build
    # ------------------------------------------------------------------
    def build(self) -> InferenceRun:
        """Full layer-wise pass; applies any queued updates first and
        publishes a new snapshot version, resetting all dirty state."""
        with self._refresh_mu:
            self._drain_apply()
            run = layerwise_layers(self.params, self.cfg, self._h0,
                                   (self.idx, self.w, self.w_self),
                                   chunk_size=self.chunk_size,
                                   mesh=self.mesh, prefetch=self.prefetch,
                                   feats_plan=self.feats_plan)
            self._publish(list(run.layers), clear_all=True)
            self.build_stats = run.stats
            with self._mu:
                self._counters["builds"] += 1
            return run

    # ------------------------------------------------------------------
    # write-ahead update log (the writer-facing API)
    # ------------------------------------------------------------------
    def mark_dirty(self, nodes) -> None:
        """Mark nodes whose layer-0 INPUT changed (features already
        written to ``graph.feats``, or changed in place)."""
        nodes = np.array(nodes, np.int64, copy=True).ravel()
        if nodes.size:
            self._append(("dirty", nodes, time.monotonic()))
            self._try_apply()

    def update_features(self, nodes, feats) -> None:
        """Queue new feature rows; they land in ``graph.feats`` (and the
        dirty mask) when the record is applied — immediately if no
        refresh is running, else at the next refresh's drain."""
        nodes = np.array(nodes, np.int64, copy=True).ravel()
        feats = np.array(feats, self.graph.feats.dtype, copy=True)
        if nodes.size:
            self._append(("feats", nodes, feats, time.monotonic()))
            self._try_apply()

    def add_edges(self, src: Sequence[int], dst: Sequence[int]) -> None:
        """Queue undirected edges (u, v); duplicates and self-loops are
        dropped.  On apply the CSR is rebuilt and the ELL rows whose
        weights moved (endpoints + every neighbor of an endpoint, since
        ã depends on both endpoint degrees) are re-derived and marked
        dirty."""
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if src.size:
            self._append(("edges", src.copy(), dst.copy(),
                          time.monotonic()))
            self._try_apply()

    def _append(self, rec: Tuple) -> None:
        with self._mu:
            self._wal.append(rec)
            if self._dirty_since is None:
                self._dirty_since = rec[-1]

    def _try_apply(self) -> None:
        """Opportunistic WAL apply: when no build/refresh is in flight,
        apply queued records right away (PR-7 eager semantics for
        single-threaded callers); under a concurrent refresh the
        records stay queued for its drain — writers never block."""
        if self._refresh_mu.acquire(blocking=False):
            try:
                self._drain_apply()
            finally:
                self._refresh_mu.release()

    def _drain_apply(self) -> int:
        """Apply every queued WAL record to the mutable build state.
        Serialized with build/refresh via ``_refresh_mu``, so applied
        arrays are never read torn by an in-flight embed."""
        with self._refresh_mu, self._mu:
            n = 0
            while self._wal:
                rec = self._wal.pop(0)
                if rec[0] == "feats":
                    self._apply_feats(rec[1], rec[2])
                elif rec[0] == "edges":
                    self._apply_edges(rec[1], rec[2])
                else:
                    self._apply_dirty(rec[1])
                n += 1
            self._applied_unpublished += n
            return n

    def _apply_dirty(self, nodes: np.ndarray) -> None:
        with self._mu:
            self._dirty_in[nodes] = True

    def _apply_feats(self, nodes: np.ndarray, feats: np.ndarray) -> None:
        with self._mu:
            self.graph.feats[nodes] = feats
            self._dirty_in[nodes] = True

    def _apply_edges(self, src: np.ndarray, dst: np.ndarray) -> None:
        with self._mu:
            g = self.graph
            old_a = np.repeat(np.arange(g.n, dtype=np.int64),
                              np.diff(g.indptr))
            old_b = g.indices.astype(np.int64)
            a = np.concatenate([old_a, src, dst])
            b = np.concatenate([old_b, dst, src])
            eid = np.unique(a * g.n + b)     # dedupe + sort by (row, col)
            a = (eid // g.n).astype(np.int64)
            b = (eid % g.n).astype(np.int32)
            indptr = np.zeros(g.n + 1, g.indptr.dtype)
            np.add.at(indptr, a + 1, 1)
            new_graph = dataclasses.replace(
                g, indptr=np.cumsum(indptr).astype(g.indptr.dtype),
                indices=b)
            # rows whose ã entries moved: endpoints + their (new)
            # neighbors
            touched = np.zeros(g.n, bool)
            ends = np.unique(np.concatenate([src, dst]))
            touched[ends] = True
            for u in ends:
                touched[new_graph.neighbors(u)] = True
            tids = np.nonzero(touched)[0].astype(np.int32)
            idx_t, w_t, ws_t = to_ell(new_graph, max_deg=self.max_deg,
                                      rows=tids)
            k_new = idx_t.shape[1]
            if k_new > self.K:               # uncapped ELL grew a column
                pad = k_new - self.K
                self.idx = np.pad(self.idx, ((0, 0), (0, pad)))
                self.w = np.pad(self.w, ((0, 0), (0, pad)))
                self.K = k_new
            self.idx[tids, :k_new] = idx_t
            self.w[tids, :k_new] = w_t
            self.w_self[tids] = ws_t
            self.graph = new_graph
            self._rev = None
            self._dirty_row[tids] = True

    # ------------------------------------------------------------------
    # dirty tracking / staleness
    # ------------------------------------------------------------------
    @property
    def dirty(self) -> bool:
        with self._mu:
            return (self._snap is None or bool(self._wal)
                    or bool(self._dirty_in.any())
                    or bool(self._dirty_row.any()))

    def pending_updates(self) -> int:
        """Update records the serving snapshot does not reflect yet
        (queued in the WAL + applied but not yet published)."""
        with self._mu:
            return len(self._wal) + self._applied_unpublished

    def staleness_s(self) -> float:
        """Seconds since the OLDEST update the serving snapshot misses
        (0.0 when fully fresh, +inf before the first build)."""
        with self._mu:
            if self._snap is None:
                return float("inf")
            if self._dirty_since is None:
                return 0.0
            return max(0.0, time.monotonic() - self._dirty_since)

    # ------------------------------------------------------------------
    # forward-influence frontier
    # ------------------------------------------------------------------
    def _reverse_index(self):
        """CSR over 'ELL rows referencing node u' (nonzero weights only;
        the self-loop contribution is implicit: w_self > 0 always, so u
        itself is added to the frontier separately via ``changed``)."""
        with self._mu:
            if self._rev is None:
                r, c = np.nonzero(self.w > 0)
                ref = self.idx[r, c]
                order = np.argsort(ref, kind="stable")
                ref_s, rows_s = ref[order], r[order].astype(np.int32)
                indptr = np.zeros(self.graph.n + 1, np.int64)
                np.add.at(indptr, ref_s.astype(np.int64) + 1, 1)
                self._rev = (np.cumsum(indptr), rows_s)
            return self._rev

    def _referencing(self, mask: np.ndarray) -> np.ndarray:
        """Bool mask of ELL rows that aggregate any node in ``mask``."""
        indptr, rows = self._reverse_index()
        out = np.zeros(self.graph.n, bool)
        nodes = np.nonzero(mask)[0]
        if nodes.size == 0:
            return out
        start, end = indptr[nodes], indptr[nodes + 1]
        counts = end - start
        total = int(counts.sum())
        if total:
            offs = np.repeat(start - np.concatenate(([0],
                             counts.cumsum()[:-1])),
                             counts) + np.arange(total)
            out[rows[offs]] = True
        return out

    def frontier(self) -> List[np.ndarray]:
        """Per-layer bool masks of the rows ``refresh()`` would re-embed
        (the k-hop forward-influence cone of the dirty set; queued WAL
        records are applied first so the preview matches the refresh)."""
        with self._refresh_mu:
            self._drain_apply()
            changed = self._dirty_in.copy()
            fronts = []
            for _ in self.params:
                need = (self._dirty_row | changed
                        | self._referencing(changed))
                fronts.append(need)
                changed = need
            return fronts

    # ------------------------------------------------------------------
    # incremental refresh
    # ------------------------------------------------------------------
    def refresh(self) -> Dict:
        """Re-embed only the dirty frontier into the NEXT snapshot
        version; equal (allclose) to a full rebuild.  The serving
        snapshot is untouched until the atomic publish at the end, so a
        crash (failpoints ``store.mid_layer_refresh`` /
        ``store.before_swap``) keeps the old version serving and the
        dirty state intact.  Returns ``{"rows_per_layer": [...],
        "total_rows": t}``."""
        with self._refresh_mu:
            if self._snap is None:
                run = self.build()
                return {"rows_per_layer": [self.graph.n]
                        * len(self.params),
                        "total_rows": self.graph.n * len(self.params),
                        "built": True, "stats": run.stats}
            self._drain_apply()
            with self._mu:
                din = self._dirty_in.copy()
                drow = self._dirty_row.copy()
                snap = self._snap
                if din.any():
                    ids = np.nonzero(din)[0]
                    self._h0 = self._h0.at[jnp.asarray(ids)].set(
                        jnp.asarray(self.graph.feats[ids]))
            if not (din.any() or drow.any()):
                return {"rows_per_layer": [0] * len(self.params),
                        "total_rows": 0}
            new_layers = list(snap.layers)
            changed = din.copy()
            rows_per_layer = []
            for li, p in enumerate(self.params):
                h = self._h0 if li == 0 else new_layers[li - 1]
                need = drow | changed | self._referencing(changed)
                ids = np.nonzero(need)[0].astype(np.int32)
                rows_per_layer.append(int(ids.size))
                if ids.size:
                    new_rows = self._embed_rows(li, p, h, ids)
                    new_layers[li] = new_layers[li].at[
                        jnp.asarray(ids)].set(new_rows)
                changed = need
                faults.maybe_crash("store.mid_layer_refresh")
            self._publish(new_layers, drained_in=din, drained_row=drow)
            with self._mu:
                self._counters["refreshes"] += 1
            return {"rows_per_layer": rows_per_layer,
                    "total_rows": int(sum(rows_per_layer))}

    def refresh_with_recovery(self, max_retries: int = 2,
                              backoff_s: float = 0.02) -> Dict:
        """``refresh()`` with PR-6's transient/fatal split: transient
        faults (``faults.TransientRefreshFault`` /
        ``TransientSamplerFault``) are retried with exponential backoff
        up to ``max_retries`` times; any OTHER incremental failure
        degrades to ONE full ``build()`` (loud RuntimeWarning) before
        surfacing; ``SimulatedCrash`` is a BaseException and always
        propagates with the old snapshot intact."""
        with self._refresh_mu:
            delay = backoff_s
            for attempt in range(max_retries + 1):
                try:
                    return self.refresh()
                except faults.TransientSamplerFault:
                    if attempt >= max_retries:
                        raise
                    with self._mu:
                        self._counters["transient_retries"] += 1
                    time.sleep(delay)
                    delay *= 2
                except Exception as e:
                    with self._mu:
                        self._counters["degraded_builds"] += 1
                    warnings.warn(
                        f"incremental refresh failed "
                        f"({type(e).__name__}: {e}) — DEGRADING to one "
                        f"full build() before surfacing",
                        RuntimeWarning, stacklevel=2)
                    run = self.build()       # raises through if it fails
                    return {"rows_per_layer": [self.graph.n]
                            * len(self.params),
                            "total_rows": self.graph.n * len(self.params),
                            "degraded": True, "stats": run.stats}

    def _publish(self, new_layers: List[jax.Array],
                 drained_in: Optional[np.ndarray] = None,
                 drained_row: Optional[np.ndarray] = None,
                 clear_all: bool = False) -> None:
        """Atomic snapshot swap; dirty state drained by THIS pass is
        cleared only here, after the new version is consistent, so an
        aborted refresh loses no invalidation."""
        final_np = np.asarray(new_layers[-1])
        faults.maybe_crash("store.before_swap")
        with self._mu:
            self._version += 1
            self._snap = TableSnapshot(self._version, tuple(new_layers),
                                       final_np, time.monotonic())
            if clear_all:
                self._dirty_in[:] = False
                self._dirty_row[:] = False
            else:
                self._dirty_in &= ~drained_in
                self._dirty_row &= ~drained_row
            self._applied_unpublished = 0
            self._dirty_since = (self._wal[0][-1] if self._wal else None)
            self._last_refresh_error = None

    def _embed_rows(self, li: int, p, h, ids: np.ndarray):
        """Layer ``li`` rows ``ids`` against the full table ``h``,
        chunk-padded to the build's chunk width so the build pass's
        compiled ``_chunk_apply`` instances are reused verbatim."""
        last = li == len(self.params) - 1
        src = _pre_source(self._scfg, p, h)
        cs = self.chunk_size
        outs = []
        for c0 in range(0, len(ids), cs):
            sel = ids[c0:c0 + cs]
            m = len(sel)
            rows_b = np.zeros(cs, np.int32)
            idx_b = np.zeros((cs, self.K), np.int32)
            w_b = np.zeros((cs, self.K), np.float32)
            ws_b = np.zeros(cs, np.float32)
            rows_b[:m] = sel
            idx_b[:m] = self.idx[sel]
            w_b[:m] = self.w[sel]
            ws_b[:m] = self.w_self[sel]
            out = _chunk_apply(self._scfg, last, self.mesh, p, h, src,
                               *jax.device_put((rows_b, idx_b, w_b, ws_b)))
            outs.append(out[:m] if m < cs else out)
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, 0)

    # ------------------------------------------------------------------
    # refresh scheduler (background re-embeds on a budget)
    # ------------------------------------------------------------------
    def start_scheduler(self, *, refresh_every_updates: Optional[int] = None,
                        refresh_budget_ms: Optional[float] = 50.0,
                        max_staleness_s: Optional[float] = None,
                        max_retries: int = 2, backoff_s: float = 0.02,
                        tick_s: float = 0.005) -> None:
        """Start the daemon refresh thread (idempotent).  It refreshes
        when ``refresh_every_updates`` records are pending, when
        staleness crosses HALF of ``max_staleness_s`` (headroom before
        the serving-side hard bound), or — with any update pending —
        once per ``refresh_budget_ms`` pacing window."""
        with self._mu:
            if self._sched_thread is not None:
                return
            self._sched_cfg = dict(every=refresh_every_updates,
                                   budget_ms=refresh_budget_ms,
                                   max_staleness_s=max_staleness_s,
                                   max_retries=max_retries,
                                   backoff_s=backoff_s, tick_s=tick_s)
            self._sched_stop.clear()
            t = threading.Thread(target=self._scheduler_loop, daemon=True)
            self._sched_thread = t
        t.start()

    def stop_scheduler(self, timeout: float = 5.0) -> None:
        """Stop and join the refresh thread (idempotent)."""
        with self._mu:
            t = self._sched_thread
            self._sched_thread = None
        if t is not None:
            self._sched_stop.set()
            t.join(timeout=timeout)

    def _scheduler_loop(self) -> None:
        cfg = self._sched_cfg
        last_end = 0.0
        while not self._sched_stop.wait(cfg["tick_s"]):
            with self._mu:
                if self._last_refresh_error is not None:
                    return           # fatal: stop; serve path surfaces it
                pending = len(self._wal) + self._applied_unpublished
                since = self._dirty_since
            if not pending and since is None:
                continue
            now = time.monotonic()
            stale = (now - since) if since is not None else 0.0
            due = False
            if cfg["every"] is not None and pending >= cfg["every"]:
                due = True
            elif (cfg["max_staleness_s"] is not None
                  and stale >= 0.5 * cfg["max_staleness_s"]):
                due = True
            elif (cfg["budget_ms"] is not None
                  and (now - last_end) * 1000.0 >= cfg["budget_ms"]):
                due = True
            if not due:
                continue
            try:
                self.refresh_with_recovery(
                    max_retries=cfg["max_retries"],
                    backoff_s=cfg["backoff_s"])
            except Exception as e:
                # fatal (retries + degrade exhausted): remember it and
                # stop scheduling — queries keep serving the last good
                # snapshot, and the serving path re-raises when its SLO
                # forces a synchronous refresh.  SimulatedCrash is a
                # BaseException: it kills this thread like a real crash.
                with self._mu:
                    self._last_refresh_error = e
                return
            with self._mu:
                self._counters["sched_refreshes"] += 1
            last_end = time.monotonic()

    @property
    def last_refresh_error(self) -> Optional[BaseException]:
        with self._mu:
            return self._last_refresh_error

    def refresh_stats(self) -> Dict:
        """Counters for the serving tier: snapshot version, pending
        update records, staleness, retry/degrade/build totals."""
        with self._mu:
            out = {"version": self._version,
                   "pending_updates": (len(self._wal)
                                       + self._applied_unpublished),
                   "last_error": (repr(self._last_refresh_error)
                                  if self._last_refresh_error else ""),
                   **dict(self._counters)}
        out["staleness_s"] = self.staleness_s()
        return out

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def predict_meta(self, nodes) -> Tuple[np.ndarray, int, float]:
        """Serve from the CURRENT snapshot without refreshing: argmax
        class per node plus ``(snapshot_version, staleness_s)`` — the
        per-query SLO metadata.  Raises if the store was never built."""
        stale = self.staleness_s()
        with self._mu:
            snap = self._snap
        if snap is None:
            raise RuntimeError(
                "EmbeddingStore has no snapshot yet — build() first")
        nodes = np.asarray(nodes, np.int64)
        return (np.argmax(snap.final_np[nodes], axis=-1),
                snap.version, stale)

    def _final_table(self) -> np.ndarray:
        """Host copy of the final-layer table (auto-refreshes first) —
        the PR-7 convenience read path for direct callers; the server
        goes through ``predict_meta`` + its own staleness SLO instead."""
        if self.dirty:
            self.refresh()
        return self.snapshot().final_np

    def query_logits(self, nodes) -> np.ndarray:
        """Final-layer logit rows for ``nodes`` (auto-refreshes)."""
        return self._final_table()[np.asarray(nodes, np.int64)]

    def predict(self, nodes) -> np.ndarray:
        """argmax class per queried node (auto-refreshes)."""
        return np.argmax(self.query_logits(nodes), axis=-1)
