"""Cached per-layer embedding tables with graph-update dirty tracking.

``EmbeddingStore`` materializes every layer's [n, d_l] table once (the
layer-wise pass from ``core.inference``) and then keeps them fresh under
point updates without full recomputes.  Invalidation follows the
FORWARD influence cone: a change to node u's layer-(l-1) embedding can
only move layer-l rows that aggregate u — u itself (self-loop) plus the
rows whose ELL lists reference u (a reverse index built from the
nonzero-weight ELL entries).  ``refresh()`` therefore re-embeds, per
layer, ``dirty_rows ∪ changed ∪ referencing(changed)`` and carries that
set forward as the next layer's ``changed`` — the k-hop frontier of the
marked nodes, NOT the whole graph.  Re-embeds go through the same
module-level compiled chunk step as the build pass (same chunk padding,
same static config), so no new compilation is paid at update time.

Two update channels (tests/test_embedding_store.py validates both
against a from-scratch store on the updated graph):

- ``update_features(nodes, feats)`` / ``mark_dirty(nodes)`` — layer-0
  inputs changed; the ELL is untouched.
- ``add_edges(src, dst)`` — structural: the CSR is rebuilt, and because
  ã weights depend on BOTH endpoint degrees, the re-derived ELL rows are
  the endpoints PLUS every current neighbor of an endpoint (their edge
  weights to the endpoint changed).  Those rows are marked dirty at
  every layer.

``core.serving`` answers classification queries from the final-layer
table via ``predict()`` (host-side argmax over a cached numpy copy —
no per-query-shape retracing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core.engine import _static_cfg
from repro.core.graph import Graph, to_ell
from repro.core.inference import (InferenceRun, _chunk_apply, _pre_source,
                                  layerwise_layers)


class EmbeddingStore:
    """Per-layer embedding cache over a (mutable) graph.

    ``max_deg=None`` keeps full neighborhoods (inference default);
    ``mesh`` routes chunk aggregation through the NODES-sharded kernel
    path (requires ``cfg.use_agg_kernel``)."""

    def __init__(self, params, cfg: GNNConfig, graph: Graph, *,
                 chunk_size: int = 1024, max_deg: Optional[int] = None,
                 mesh=None, prefetch: bool = True):
        self.params = params
        self.cfg = cfg
        self._scfg = _static_cfg(cfg)
        self.graph = graph
        self.max_deg = max_deg
        self.mesh = mesh
        self.prefetch = prefetch
        self.chunk_size = max(1, min(int(chunk_size), graph.n))
        self.idx, self.w, self.w_self = to_ell(graph, max_deg=max_deg)
        self.K = self.idx.shape[1]
        self._h0 = jnp.asarray(graph.feats)
        # feats_layout="sharded": the full build runs the NODES-sharded
        # featshard pass (no replicated table); incremental refreshes
        # keep the chunked path — dirty frontiers are tiny row sets
        self.feats_plan = None
        if (cfg.feats_layout == "sharded" and cfg.use_agg_kernel
                and mesh is not None
                and cfg.model in ("gcn", "graphsage")):
            from repro import sharding as sh
            from repro.kernels.neighbor_agg.ops import build_featshard_plan
            pad = (-graph.n) % sh.nodes_shards(mesh)
            idx_p = (np.pad(self.idx, ((0, pad), (0, 0)))
                     if pad else self.idx)
            w_p = np.pad(self.w, ((0, pad), (0, 0))) if pad else self.w
            self.feats_plan = build_featshard_plan(
                idx_p, w_p, graph.degrees, mesh,
                cache_rows=cfg.feat_cache_rows)
        self.layers: Optional[List[jax.Array]] = None
        self.build_stats: Optional[Dict] = None
        self._dirty_in = np.zeros(graph.n, bool)    # layer-0 inputs moved
        self._dirty_row = np.zeros(graph.n, bool)   # ELL row re-derived
        self._rev = None                            # lazy reverse index
        self._final_np: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    # build
    # ------------------------------------------------------------------
    def build(self) -> InferenceRun:
        """Full layer-wise pass; resets all dirty state."""
        run = layerwise_layers(self.params, self.cfg, self._h0,
                               (self.idx, self.w, self.w_self),
                               chunk_size=self.chunk_size, mesh=self.mesh,
                               prefetch=self.prefetch,
                               feats_plan=self.feats_plan)
        self.layers = list(run.layers)
        self.build_stats = run.stats
        self._dirty_in[:] = False
        self._dirty_row[:] = False
        self._final_np = None
        return run

    # ------------------------------------------------------------------
    # dirty tracking
    # ------------------------------------------------------------------
    @property
    def dirty(self) -> bool:
        return (self.layers is None or bool(self._dirty_in.any())
                or bool(self._dirty_row.any()))

    def mark_dirty(self, nodes) -> None:
        """Mark nodes whose layer-0 INPUT changed (features already
        written to ``graph.feats``, or changed in place)."""
        self._dirty_in[np.asarray(nodes, np.int64)] = True

    def update_features(self, nodes, feats) -> None:
        """Write new feature rows and mark them dirty."""
        nodes = np.asarray(nodes, np.int64)
        self.graph.feats[nodes] = np.asarray(feats, self.graph.feats.dtype)
        self.mark_dirty(nodes)

    def add_edges(self, src: Sequence[int], dst: Sequence[int]) -> None:
        """Add undirected edges (u, v); duplicates and self-loops are
        dropped.  Rebuilds the CSR, re-derives the ELL rows whose
        weights moved (endpoints + every neighbor of an endpoint, since
        ã depends on both endpoint degrees) and marks them dirty."""
        g = self.graph
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        keep = src != dst
        src, dst = src[keep], dst[keep]
        if src.size == 0:
            return
        old_a = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
        old_b = g.indices.astype(np.int64)
        a = np.concatenate([old_a, src, dst])
        b = np.concatenate([old_b, dst, src])
        eid = np.unique(a * g.n + b)         # dedupe + sort by (row, col)
        a = (eid // g.n).astype(np.int64)
        b = (eid % g.n).astype(np.int32)
        indptr = np.zeros(g.n + 1, g.indptr.dtype)
        np.add.at(indptr, a + 1, 1)
        new_graph = dataclasses.replace(
            g, indptr=np.cumsum(indptr).astype(g.indptr.dtype),
            indices=b)
        # rows whose ã entries moved: endpoints + their (new) neighbors
        touched = np.zeros(g.n, bool)
        ends = np.unique(np.concatenate([src, dst]))
        touched[ends] = True
        for u in ends:
            touched[new_graph.neighbors(u)] = True
        tids = np.nonzero(touched)[0].astype(np.int32)
        idx_t, w_t, ws_t = to_ell(new_graph, max_deg=self.max_deg,
                                  rows=tids)
        k_new = idx_t.shape[1]
        if k_new > self.K:                   # uncapped ELL grew a column
            pad = k_new - self.K
            self.idx = np.pad(self.idx, ((0, 0), (0, pad)))
            self.w = np.pad(self.w, ((0, 0), (0, pad)))
            self.K = k_new
        self.idx[tids, :k_new] = idx_t
        self.w[tids, :k_new] = w_t
        self.w_self[tids] = ws_t
        self.graph = new_graph
        self._rev = None
        self._dirty_row[tids] = True
        self._final_np = None

    # ------------------------------------------------------------------
    # forward-influence frontier
    # ------------------------------------------------------------------
    def _reverse_index(self):
        """CSR over 'ELL rows referencing node u' (nonzero weights only;
        the self-loop contribution is implicit: w_self > 0 always, so u
        itself is added to the frontier separately via ``changed``)."""
        if self._rev is None:
            r, c = np.nonzero(self.w > 0)
            ref = self.idx[r, c]
            order = np.argsort(ref, kind="stable")
            ref_s, rows_s = ref[order], r[order].astype(np.int32)
            indptr = np.zeros(self.graph.n + 1, np.int64)
            np.add.at(indptr, ref_s.astype(np.int64) + 1, 1)
            self._rev = (np.cumsum(indptr), rows_s)
        return self._rev

    def _referencing(self, mask: np.ndarray) -> np.ndarray:
        """Bool mask of ELL rows that aggregate any node in ``mask``."""
        indptr, rows = self._reverse_index()
        out = np.zeros(self.graph.n, bool)
        nodes = np.nonzero(mask)[0]
        if nodes.size == 0:
            return out
        start, end = indptr[nodes], indptr[nodes + 1]
        counts = end - start
        total = int(counts.sum())
        if total:
            offs = np.repeat(start - np.concatenate(([0], counts.cumsum()[:-1])),
                             counts) + np.arange(total)
            out[rows[offs]] = True
        return out

    def frontier(self) -> List[np.ndarray]:
        """Per-layer bool masks of the rows ``refresh()`` would re-embed
        (the k-hop forward-influence cone of the dirty set)."""
        changed = self._dirty_in.copy()
        fronts = []
        for _ in self.params:
            need = self._dirty_row | changed | self._referencing(changed)
            fronts.append(need)
            changed = need
        return fronts

    # ------------------------------------------------------------------
    # incremental refresh
    # ------------------------------------------------------------------
    def refresh(self) -> Dict:
        """Re-embed only the dirty frontier; equal (allclose) to a full
        rebuild.  Returns ``{"rows_per_layer": [...], "total_rows": t}``."""
        if self.layers is None:
            run = self.build()
            return {"rows_per_layer": [self.graph.n] * len(self.params),
                    "total_rows": self.graph.n * len(self.params),
                    "built": True, "stats": run.stats}
        if not self.dirty:
            return {"rows_per_layer": [0] * len(self.params),
                    "total_rows": 0}
        if self._dirty_in.any():
            ids = np.nonzero(self._dirty_in)[0]
            self._h0 = self._h0.at[jnp.asarray(ids)].set(
                jnp.asarray(self.graph.feats[ids]))
        changed = self._dirty_in.copy()
        rows_per_layer = []
        for li, p in enumerate(self.params):
            h = self._h0 if li == 0 else self.layers[li - 1]
            need = self._dirty_row | changed | self._referencing(changed)
            ids = np.nonzero(need)[0].astype(np.int32)
            rows_per_layer.append(int(ids.size))
            if ids.size:
                new_rows = self._embed_rows(li, p, h, ids)
                self.layers[li] = self.layers[li].at[
                    jnp.asarray(ids)].set(new_rows)
            changed = need
        self._dirty_in[:] = False
        self._dirty_row[:] = False
        self._final_np = None
        return {"rows_per_layer": rows_per_layer,
                "total_rows": int(sum(rows_per_layer))}

    def _embed_rows(self, li: int, p, h, ids: np.ndarray):
        """Layer ``li`` rows ``ids`` against the full table ``h``,
        chunk-padded to the build's chunk width so the build pass's
        compiled ``_chunk_apply`` instances are reused verbatim."""
        last = li == len(self.params) - 1
        src = _pre_source(self._scfg, p, h)
        cs = self.chunk_size
        outs = []
        for c0 in range(0, len(ids), cs):
            sel = ids[c0:c0 + cs]
            m = len(sel)
            rows_b = np.zeros(cs, np.int32)
            idx_b = np.zeros((cs, self.K), np.int32)
            w_b = np.zeros((cs, self.K), np.float32)
            ws_b = np.zeros(cs, np.float32)
            rows_b[:m] = sel
            idx_b[:m] = self.idx[sel]
            w_b[:m] = self.w[sel]
            ws_b[:m] = self.w_self[sel]
            out = _chunk_apply(self._scfg, last, self.mesh, p, h, src,
                               *jax.device_put((rows_b, idx_b, w_b, ws_b)))
            outs.append(out[:m] if m < cs else out)
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, 0)

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def _final_table(self) -> np.ndarray:
        """Host copy of the final-layer table (auto-refreshes first);
        cached so serving batches of ANY size are numpy slices, not
        per-shape jit retraces."""
        if self.dirty:
            self.refresh()
        if self._final_np is None:
            self._final_np = np.asarray(self.layers[-1])
        return self._final_np

    def query_logits(self, nodes) -> np.ndarray:
        """Final-layer logit rows for ``nodes`` (auto-refreshes)."""
        return self._final_table()[np.asarray(nodes, np.int64)]

    def predict(self, nodes) -> np.ndarray:
        """argmax class per queried node (auto-refreshes)."""
        return np.argmax(self.query_logits(nodes), axis=-1)
