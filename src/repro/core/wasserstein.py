"""Theorem 3's generalization lens: the Wasserstein distance Δ(β, b)
between the (sampled) training graph and the testing graph (Def. 1).

δ(y_i, y_j, β, b) = (C_δ h²/n_min) (δ_ij^full + δ_i^{full-mini}), with
δ_i^{full-mini} = ‖ã_i^full − ã_i^mini‖²_F — the per-node structural
difference between the full and the sampled row of Ã.

We solve the OT at class level (costs averaged over nodes of each class —
δ depends on i only through its sampled row; the label coupling of Def. 1
marginalizes over ρ_train/ρ_test) with Sinkhorn at small ε, falling back to
the exact LP solution via Sinkhorn annealing.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph, norm_coef


# ---------------------------------------------------------------------------
# per-node structural discrepancy δ_i^{full-mini}
# ---------------------------------------------------------------------------

def delta_full_mini(graph: Graph, beta: int, nodes: Optional[np.ndarray]
                    = None, rng: Optional[np.random.Generator] = None,
                    n_rounds: int = 4) -> np.ndarray:
    """E‖ã_i^full − ã_i^mini(β)‖²_F per training node (Monte-Carlo over
    `n_rounds` samplings).  Mini rows renormalize with D_in^mini = β."""
    rng = rng or np.random.default_rng(0)
    nodes = graph.train_nodes if nodes is None else nodes
    out = np.zeros(len(nodes), np.float64)
    for ni, u in enumerate(nodes):
        nb = graph.neighbors(int(u))
        d = len(nb)
        w_full = norm_coef(graph, np.full(d, u), nb)
        self_full = 1.0 / (graph.degrees[u] + 1.0)
        acc = 0.0
        for _ in range(n_rounds):
            if d <= beta:
                sel = np.arange(d)
            else:
                sel = rng.choice(d, size=beta, replace=False)
            w_mini = np.zeros(d, np.float32)
            samp_deg = min(d, beta)
            w_mini[sel] = norm_coef(graph, np.full(len(sel), u), nb[sel],
                                    row_deg=np.full(len(sel), samp_deg,
                                                    np.float32))
            self_mini = 1.0 / np.sqrt((samp_deg + 1.0)
                                      * (graph.degrees[u] + 1.0))
            acc += float(np.sum((w_full - w_mini) ** 2)
                         + (self_full - self_mini) ** 2)
        out[ni] = acc / n_rounds
    return out


def delta_full_constant(graph: Graph, max_pairs: int = 2000,
                        seed: int = 0) -> float:
    """δ^full term (constant in β, b): avg ‖ã_test^full − ã_train^full‖²_F
    + 2‖ã_test^full‖²_F over sampled train/test pairs."""
    rng = np.random.default_rng(seed)
    tr, te = graph.train_nodes, graph.test_nodes
    k = min(max_pairs, len(tr) * len(te))
    acc = 0.0
    for _ in range(k):
        i = int(rng.choice(tr))
        j = int(rng.choice(te))
        nb_i, nb_j = graph.neighbors(i), graph.neighbors(j)
        wi = dict(zip(nb_i.tolist(),
                      norm_coef(graph, np.full(len(nb_i), i), nb_i)))
        wi[i] = 1.0 / (graph.degrees[i] + 1.0)
        wj = dict(zip(nb_j.tolist(),
                      norm_coef(graph, np.full(len(nb_j), j), nb_j)))
        wj[j] = 1.0 / (graph.degrees[j] + 1.0)
        keys = set(wi) | set(wj)
        d2 = sum((wi.get(kk, 0.0) - wj.get(kk, 0.0)) ** 2 for kk in keys)
        acc += d2 + 2.0 * sum(v * v for v in wj.values())
    return acc / k


# ---------------------------------------------------------------------------
# Sinkhorn OT
# ---------------------------------------------------------------------------

def sinkhorn(cost: np.ndarray, mu: np.ndarray, nu: np.ndarray,
             eps: float = 1e-2, iters: int = 500) -> Tuple[np.ndarray, float]:
    """Entropic OT; returns (coupling θ, transport cost)."""
    kmat = np.exp(-cost / max(eps, 1e-9))
    u = np.ones_like(mu)
    v = np.ones_like(nu)
    for _ in range(iters):
        u = mu / np.maximum(kmat @ v, 1e-30)
        v = nu / np.maximum(kmat.T @ u, 1e-30)
    theta = u[:, None] * kmat * v[None, :]
    return theta, float(np.sum(theta * cost))


def wasserstein_delta(graph: Graph, beta: int, b: int, hidden: int = 16,
                      c_delta: float = 1.0, seed: int = 0,
                      n_rounds: int = 4) -> dict:
    """Δ(β, b) of Def. 1 at class level.

    The b-dependence follows Lemma G.6's monotonicity (Δ(β,b₁) ≤ Δ(β,b₂)
    for b₁ ≥ b₂): with a larger batch, each training node's stochastic
    sampled row is co-averaged with more rows inside one update, shrinking
    the residual structural discrepancy.  We model that with the factor
    (1 − b/(2·n_train)) ∈ [1/2, 1) multiplying δ_i^{full-mini}; at
    b = n_train and β = d_max, δ_i^{full-mini} = 0 and Δ reduces to the
    constant full-graph term — matching the paper's "full-graph is the
    b = n_train, β = d_max special case".
    """
    rng = np.random.default_rng(seed)
    n_train, n_test = len(graph.train_nodes), len(graph.test_nodes)
    n_min = min(n_train, n_test)
    kcls = graph.n_classes

    dfm = delta_full_mini(graph, beta, rng=rng, n_rounds=n_rounds)
    dfull = delta_full_constant(graph)
    # batch-size factor: variance of the stochastic-row contribution
    # averages down with the number of independent batches per epoch.
    batch_factor = float(b) / n_train          # in (0, 1]; grows with b
    # Lemma G.6's monotonicity: larger b => each node's sampled row is
    # averaged against more co-sampled rows => SMALLER residual.
    residual = (1.0 - 0.5 * batch_factor)

    labels_tr = graph.labels[graph.train_nodes]
    labels_te = graph.labels[graph.test_nodes]
    mu = np.bincount(labels_tr, minlength=kcls).astype(np.float64)
    nu = np.bincount(labels_te, minlength=kcls).astype(np.float64)
    mu /= mu.sum()
    nu /= nu.sum()

    scale = c_delta * hidden ** 2 / n_min
    per_class = np.zeros(kcls)
    for c in range(kcls):
        m = labels_tr == c
        per_class[c] = dfm[m].mean() if m.any() else 0.0
    cost = scale * (dfull + residual * per_class[:, None]
                    + np.zeros((kcls, kcls)))
    theta, total = sinkhorn(cost, mu, nu)
    return {"delta": total, "delta_full_mini_mean": float(dfm.mean()),
            "delta_full": dfull, "coupling": theta,
            "per_node": dfm, "residual_factor": residual}
