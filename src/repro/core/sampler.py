"""Mini-batch sampling: batch-size b node sampling + fan-out β uniform
neighbor sampling per hop (GraphSAGE semantics, paper §2).

Produces padded fan-out trees: hop d has ids [b, f1, ..., fd], a validity
mask, and ã^mini edge weights computed from the SAMPLED in-degree
(the paper's D_in^mini) and the global out-degree (columns of A_train^mini
live in R^n).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph, norm_coef


@dataclasses.dataclass
class FanoutBatch:
    """One sampled mini-batch (hop 0 = target nodes)."""
    nodes: List[np.ndarray]     # hop d: int32 [b, f1..fd]
    masks: List[np.ndarray]     # hop d >= 1: bool, False = padding
    weights: List[np.ndarray]   # hop d >= 1: float32 ã^mini per edge
    self_w: List[np.ndarray]    # hop d >= 0: float32 self-loop weight
    labels: np.ndarray          # [b]

    @property
    def batch_size(self) -> int:
        return len(self.nodes[0])


def sample_neighbors(rng: np.random.Generator, graph: Graph,
                     src: np.ndarray, fanout: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Uniform sampling WITHOUT replacement per node (DGL semantics):
    nodes with degree <= β keep all neighbors; the rest are padding."""
    flat = src.reshape(-1)
    out = np.zeros((flat.size, fanout), np.int32)
    mask = np.zeros((flat.size, fanout), bool)
    for i, u in enumerate(flat):
        nb = graph.neighbors(int(u))
        if len(nb) == 0:
            continue
        if len(nb) <= fanout:
            out[i, :len(nb)] = nb
            mask[i, :len(nb)] = True
        else:
            sel = rng.choice(nb, size=fanout, replace=False)
            out[i] = sel
            mask[i] = True
    return (out.reshape(src.shape + (fanout,)),
            mask.reshape(src.shape + (fanout,)))


def sample_batch(rng: np.random.Generator, graph: Graph, batch_size: int,
                 fanouts: Sequence[int]) -> FanoutBatch:
    """Sample b target nodes then β_d neighbors per hop."""
    train = graph.train_nodes
    b = min(batch_size, len(train))
    targets = rng.choice(train, size=b, replace=False).astype(np.int32)
    return expand_batch(rng, graph, targets, fanouts)


def expand_batch(rng: np.random.Generator, graph: Graph,
                 targets: np.ndarray, fanouts: Sequence[int]) -> FanoutBatch:
    nodes = [targets]
    masks: List[np.ndarray] = []
    weights: List[np.ndarray] = []
    self_w: List[np.ndarray] = []
    deg = graph.degrees
    self_w.append((1.0 / (deg[targets] + 1.0)).astype(np.float32))
    cur = targets
    for beta in fanouts:
        nb, mk = sample_neighbors(rng, graph, cur, beta)
        # D_in^mini: number of actually-sampled in-neighbors per row
        samp_deg = mk.sum(-1).astype(np.float32)
        rows = np.broadcast_to(cur[..., None], nb.shape).reshape(-1)
        row_deg = np.broadcast_to(samp_deg[..., None], nb.shape).reshape(-1)
        w = norm_coef(graph, rows, nb.reshape(-1), row_deg=row_deg)
        w = (w.reshape(nb.shape) * mk).astype(np.float32)
        nodes.append(nb)
        masks.append(mk)
        weights.append(w)
        self_w.append((1.0 / (deg[nb.reshape(-1)] + 1.0))
                      .reshape(nb.shape).astype(np.float32))
        cur = nb
    return FanoutBatch(nodes=nodes, masks=masks, weights=weights,
                       self_w=self_w,
                       labels=graph.labels[targets].astype(np.int32))


def gather_features(graph: Graph, batch: FanoutBatch) -> List[np.ndarray]:
    """Host-side feature gather per hop (the paper's CPU->GPU loading path;
    on TPU this is the infeed)."""
    return [graph.feats[ids.reshape(-1)].reshape(ids.shape + (-1,))
            for ids in batch.nodes]
