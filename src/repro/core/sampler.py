"""Mini-batch sampling: batch-size b node sampling + fan-out β uniform
neighbor sampling per hop (GraphSAGE semantics, paper §2).

Produces padded fan-out trees: hop d has ids [b, f1, ..., fd], a validity
mask, and ã^mini edge weights computed from the SAMPLED in-degree
(the paper's D_in^mini) and the global out-degree (columns of A_train^mini
live in R^n).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph, norm_coef


@dataclasses.dataclass
class FanoutBatch:
    """One sampled mini-batch (hop 0 = target nodes)."""
    nodes: List[np.ndarray]     # hop d: int32 [b, f1..fd]
    masks: List[np.ndarray]     # hop d >= 1: bool, False = padding
    weights: List[np.ndarray]   # hop d >= 1: float32 ã^mini per edge
    self_w: List[np.ndarray]    # hop d >= 0: float32 self-loop weight
    labels: np.ndarray          # [b]
    #: optional per-target loss weight (importance sampling: 1/(n·p_j),
    #: preserving E[weighted batch loss] == full training loss)
    target_w: Optional[np.ndarray] = None

    @property
    def batch_size(self) -> int:
        return len(self.nodes[0])


def sample_neighbors_loop(rng: np.random.Generator, graph: Graph,
                          src: np.ndarray, fanout: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Seed per-node-loop sampler (one rng.choice per node).  Kept as the
    semantics reference for equivalence tests and the bench_sampler.py
    baseline — use `sample_neighbors` (vectorized CSR) everywhere else."""
    flat = src.reshape(-1)
    out = np.zeros((flat.size, fanout), np.int32)
    mask = np.zeros((flat.size, fanout), bool)
    for i, u in enumerate(flat):
        nb = graph.neighbors(int(u))
        if len(nb) == 0:
            continue
        if len(nb) <= fanout:
            out[i, :len(nb)] = nb
            mask[i, :len(nb)] = True
        else:
            sel = rng.choice(nb, size=fanout, replace=False)
            out[i] = sel
            mask[i] = True
    return (out.reshape(src.shape + (fanout,)),
            mask.reshape(src.shape + (fanout,)))


def sample_neighbors(rng: np.random.Generator, graph: Graph,
                     src: np.ndarray, fanout: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized CSR uniform sampling WITHOUT replacement (DGL semantics,
    identical to `sample_neighbors_loop`): nodes with degree <= β keep ALL
    neighbors; higher-degree nodes get β distinct uniform picks.

    No per-node Python loop: low-degree rows are one batched ragged CSR
    gather; high-degree rows draw random sort keys over their padded
    neighbor lists and argpartition the β smallest (exactly uniform
    without replacement).
    """
    flat = src.reshape(-1).astype(np.int64)
    m = flat.size
    out = np.zeros((m, fanout), np.int32)
    mask = np.zeros((m, fanout), bool)
    indptr, indices = graph.indptr, graph.indices
    if m == 0 or indices.size == 0:          # empty batch / edgeless graph
        return (out.reshape(src.shape + (fanout,)),
                mask.reshape(src.shape + (fanout,)))
    start = indptr[flat]
    deg = (indptr[flat + 1] - start).astype(np.int64)

    small = deg <= fanout
    if small.any():
        s = np.nonzero(small)[0]
        s_deg, s_start = deg[s], start[s]
        cols = np.arange(fanout, dtype=np.int64)[None, :]
        keep = cols < s_deg[:, None]
        pos = np.where(keep, s_start[:, None] + cols, 0)
        out[s] = np.where(keep, indices[pos], 0)
        mask[s] = keep

    big = ~small
    if big.any():
        bidx = np.nonzero(big)[0]
        b_deg, b_start = deg[bidx], start[bidx]
        # bucket rows by degree (width doubles per bucket) so the position
        # matrix is padded to <= 2x each row's degree, not the global max
        # degree — total work stays O(sum deg) on power-law graphs
        order = np.argsort(b_deg, kind="stable")
        sdeg = b_deg[order]
        # one batch of randoms for every swap round of every big row
        # (a single rng call; per-bucket rng calls dominate otherwise)
        u = rng.random((fanout, bidx.size), dtype=np.float32)
        lo = 0
        while lo < order.size:
            d0 = int(sdeg[lo])
            # dense regime (β < deg < 2β), big exact-degree run: sample
            # the (deg - β)-element COMPLEMENT instead — uniform exclusion
            # ⇒ uniform kept set, with deg - β < β swap rounds and a pos
            # matrix of width exactly deg (no padding)
            hi_eq = int(np.searchsorted(sdeg, d0, side="right"))
            if d0 < 2 * fanout and hi_eq - lo >= 96:
                grp = order[lo:hi_eq]
                g_start = b_start[grp]
                gm = grp.size
                k = d0 - fanout
                pdt = (np.int8 if d0 < 2 ** 7 else
                       np.int16 if d0 < 2 ** 15 else np.int32)
                # TRANSPOSED position matrix [d0, gm]: the per-round
                # column ops become contiguous gm-byte slices instead of
                # strided reads that pull a full cache line per element
                pos = np.broadcast_to(
                    np.arange(d0, dtype=pdt)[:, None], (d0, gm)).copy()
                posf = pos.reshape(-1)
                rows = np.arange(gm, dtype=np.int64)
                ug = u[:, grp]
                for j in range(k):
                    tcol = d0 - 1 - j          # FY from the top: move an
                    r = (ug[j] * (d0 - j)).astype(np.int64)  # excluded
                    np.minimum(r, d0 - j - 1, out=r)         # pick to the
                    rf = r * gm + rows                       # tail
                    pj = pos[tcol].copy()
                    pos[tcol] = posf[rf]
                    posf[rf] = pj
                out[bidx[grp]] = indices[g_start[:, None]
                                         + pos[:fanout].T]
                lo = hi_eq
                continue
            width = d0
            hi = int(np.searchsorted(sdeg, 2 * width, side="right"))
            grp = order[lo:hi]
            g_deg, g_start = b_deg[grp], b_start[grp]
            width = int(sdeg[hi - 1])
            # partial Fisher-Yates, vectorized over rows: after β swap
            # rounds, rows [0, β) of the TRANSPOSED [width, gm] position
            # matrix hold a uniform without-replacement draw from each
            # row's first g_deg positions.  Transposed layout + the
            # narrowest dtype that holds a position id (usually int8)
            # keep the per-round traffic at contiguous gm-byte slices
            # plus one random gather + one random scatter.
            gm = grp.size
            pdt = (np.int8 if width < 2 ** 7 else
                   np.int16 if width < 2 ** 15 else np.int32)
            pos = np.broadcast_to(np.arange(width, dtype=pdt)[:, None],
                                  (width, gm)).copy()
            posf = pos.reshape(-1)
            rows = np.arange(gm, dtype=np.int64)
            # all swap targets batched in one vectorized shot:
            # rcols[j] ~ Uniform{j, ..., deg-1} per row, flat-indexed
            # into the transposed matrix (position p of row i = p*gm + i)
            js = np.arange(fanout, dtype=np.int64)[:, None]
            rcols = (u[:, grp] * (g_deg[None, :] - js)).astype(np.int64) + js
            np.minimum(rcols, g_deg[None, :] - 1, out=rcols)  # f32 guard
            rcols *= gm
            rcols += rows[None, :]
            # round 0 reads an untouched permutation: pos[0] == 0 and
            # posf[r] == its own position id — skip both gathers
            r0 = rcols[0]
            pos[0] = (r0 // gm).astype(pdt)
            posf[r0] = 0
            for j in range(1, fanout):
                r = rcols[j]
                pj = pos[j].copy()                       # contiguous
                pos[j] = posf[r]
                posf[r] = pj
            out[bidx[grp]] = indices[g_start[:, None] + pos[:fanout].T]
            lo = hi
        mask[bidx] = True
    return (out.reshape(src.shape + (fanout,)),
            mask.reshape(src.shape + (fanout,)))


NeighborSampler = Callable[[np.random.Generator, Graph, np.ndarray, int],
                           Tuple[np.ndarray, np.ndarray]]


def sample_batch(rng: np.random.Generator, graph: Graph, batch_size: int,
                 fanouts: Sequence[int],
                 neighbor_sampler: Optional[NeighborSampler] = None,
                 strict: bool = False) -> FanoutBatch:
    """Sample b target nodes then β_d neighbors per hop.

    ``batch_size > n_train`` clamps to n_train by default (the engine
    pads such partial batches back up to a fixed compiled width); with
    ``strict=True`` it raises instead.  A graph without training nodes
    always raises — ``rng.choice`` on the empty split used to surface
    it as a bare numpy ValueError deep in the call.
    """
    train = graph.train_nodes
    n_train = len(train)
    if batch_size < 1:
        raise ValueError(f"sample_batch: batch_size must be >= 1, got "
                         f"b={batch_size}")
    if n_train == 0:
        raise ValueError(
            f"sample_batch: graph has no training nodes (b={batch_size}, "
            f"n_train=0) — check graph.train_mask")
    if strict and batch_size > n_train:
        raise ValueError(
            f"sample_batch: batch_size exceeds the training split "
            f"(b={batch_size} > n_train={n_train}); pass a smaller b or "
            f"let the engine pad (strict=False clamps to n_train)")
    b = min(batch_size, n_train)
    targets = rng.choice(train, size=b, replace=False).astype(np.int32)
    return expand_batch(rng, graph, targets, fanouts,
                        neighbor_sampler=neighbor_sampler)


def expand_batch(rng: np.random.Generator, graph: Graph,
                 targets: np.ndarray, fanouts: Sequence[int],
                 neighbor_sampler: Optional[NeighborSampler] = None
                 ) -> FanoutBatch:
    sampler = neighbor_sampler or sample_neighbors
    nodes = [targets]
    masks: List[np.ndarray] = []
    weights: List[np.ndarray] = []
    self_w: List[np.ndarray] = []
    deg = graph.degrees
    self_w.append((1.0 / (deg[targets] + 1.0)).astype(np.float32))
    cur = targets
    for beta in fanouts:
        nb, mk = sampler(rng, graph, cur, beta)
        # D_in^mini: number of actually-sampled in-neighbors per row
        samp_deg = mk.sum(-1).astype(np.float32)
        rows = np.broadcast_to(cur[..., None], nb.shape).reshape(-1)
        row_deg = np.broadcast_to(samp_deg[..., None], nb.shape).reshape(-1)
        w = norm_coef(graph, rows, nb.reshape(-1), row_deg=row_deg)
        w = (w.reshape(nb.shape) * mk).astype(np.float32)
        nodes.append(nb)
        masks.append(mk)
        weights.append(w)
        self_w.append((1.0 / (deg[nb.reshape(-1)] + 1.0))
                      .reshape(nb.shape).astype(np.float32))
        cur = nb
    return FanoutBatch(nodes=nodes, masks=masks, weights=weights,
                       self_w=self_w,
                       labels=graph.labels[targets].astype(np.int32))


def gather_features(graph: Graph, batch: FanoutBatch) -> List[np.ndarray]:
    """Host-side feature gather per hop (the paper's CPU->GPU loading path;
    on TPU this is the infeed)."""
    return [graph.feats[ids.reshape(-1)].reshape(ids.shape + (-1,))
            for ids in batch.nodes]
