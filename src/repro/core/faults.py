"""Deterministic fault injection: failpoints + seeded chaos schedules.

The resilience layer (exact-resume checkpoints, non-finite step guards,
supervised prefetch, crash-safe sweeps) is only trustworthy if its
recovery paths are *exercised*, deterministically, in CI.  This module
is the injection side of that contract:

- **Failpoints** — named crash sites compiled into the production code
  (``_maybe_crash("ckpt.after_npz_rename")`` in ``checkpoint.ckpt``,
  ``"sweep.after_point"`` in ``core.experiment``).  They are inert
  no-ops (one dict lookup on an empty dict) until a test ``arm()``s
  them, after which the N-th hit raises ``SimulatedCrash`` — a
  ``BaseException`` so it sails through ``except Exception`` recovery
  code exactly like a SIGKILL would end the process.
- **Flaky callables** — ``flaky(fn, fail_at={...})`` wraps a sampler /
  payload function so specific *invocations* raise.  Transient faults
  (``TransientSamplerFault``) drive the Prefetcher's supervised
  restart; ``FatalSamplerFault`` (or any other exception) must surface
  to the caller instead.
- **Batch poisoning** — ``poison_batches(source, at_iters)`` rewrites a
  ``BatchSource``'s device batches so every float leaf at the chosen
  iterations is NaN, driving the engine's non-finite step guard and
  ``BadStepPolicy`` without touching model code.
- **Seeded schedules** — ``FaultSchedule(seed)`` picks *which* batches
  / calls / steps to break from a fixed-seed rng, so a chaos suite is
  reproducible: same fault seed, same faults, same recovery sequence.

Everything here is test/ops tooling: importing it pulls in nothing
heavier than numpy, and with no failpoints armed the production-code
hooks cost one ``dict.get`` on an empty dict.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Dict, Iterable, Optional, Set

import numpy as np


class SimulatedCrash(BaseException):
    """An injected hard crash (kill -9 stand-in).  Deliberately NOT an
    ``Exception``: recovery code that catches ``Exception`` (the sweep's
    per-point isolation, the Prefetcher's restart supervision) must let
    a real process death through, and tests verify exactly that."""


class TransientSamplerFault(RuntimeError):
    """A worker error the Prefetcher classifies as TRANSIENT: the
    supervised worker restarts (bounded exponential backoff) and replays
    the same batch from the pre-draw rng snapshot."""


class FatalSamplerFault(RuntimeError):
    """A worker error the Prefetcher classifies as FATAL: stored and
    re-raised on every subsequent ``next()``."""


class TransientRefreshFault(TransientSamplerFault):
    """A serving-side refresh error classified as TRANSIENT: the
    embedding store's ``refresh_with_recovery`` retries it with
    exponential backoff (same transient/fatal split as the sampler)."""


# ---------------------------------------------------------------------------
# Failpoints
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FailPoint:
    name: str
    at_hits: Set[int]
    exc: Callable[[str], BaseException]
    hits: int = 0

    def check(self) -> None:
        idx, self.hits = self.hits, self.hits + 1
        if idx in self.at_hits:
            raise self.exc(f"failpoint {self.name!r} hit #{idx}")


_ACTIVE: Dict[str, FailPoint] = {}


def arm(name: str, at_hits: Iterable[int] = (0,),
        exc: Callable[[str], BaseException] = SimulatedCrash) -> FailPoint:
    """Arm failpoint ``name``: its ``at_hits``-th invocations (0-based,
    counted from arming) raise ``exc(message)``."""
    fp = FailPoint(name, set(int(i) for i in at_hits), exc)
    _ACTIVE[name] = fp
    return fp


def disarm(name: Optional[str] = None) -> None:
    """Disarm one failpoint (or all of them with ``name=None``)."""
    if name is None:
        _ACTIVE.clear()
    else:
        _ACTIVE.pop(name, None)


def maybe_crash(name: str) -> None:
    """The production-code hook: no-op unless ``name`` is armed."""
    fp = _ACTIVE.get(name)
    if fp is not None:
        fp.check()


@contextlib.contextmanager
def armed(name: str, at_hits: Iterable[int] = (0,),
          exc: Callable[[str], BaseException] = SimulatedCrash):
    """``with faults.armed("ckpt.after_npz_rename"): ...`` — arm for the
    block, always disarm on exit (even when the crash propagates)."""
    fp = arm(name, at_hits, exc)
    try:
        yield fp
    finally:
        disarm(name)


# ---------------------------------------------------------------------------
# Flaky callables
# ---------------------------------------------------------------------------

def flaky(fn: Callable, fail_at: Iterable[int],
          exc: Callable[[str], BaseException] = TransientSamplerFault
          ) -> Callable:
    """Wrap ``fn`` so its ``fail_at``-th *invocations* (0-based) raise.

    Retries count as new invocations: with ``fail_at={2}`` call #2
    raises and the retry (call #3, typically replaying the same batch
    from a restored rng state) succeeds — the shape of a transient
    fault."""
    hit = set(int(i) for i in fail_at)
    calls = {"n": 0}

    @functools.wraps(fn)
    def wrapper(*a, **kw):
        idx, calls["n"] = calls["n"], calls["n"] + 1
        if idx in hit:
            raise exc(f"injected fault at call #{idx} of "
                      f"{getattr(fn, '__name__', fn)!r}")
        return fn(*a, **kw)

    wrapper.calls = calls
    return wrapper


# ---------------------------------------------------------------------------
# Batch poisoning (NaN-at-step-k)
# ---------------------------------------------------------------------------

def _nanify(leaf):
    import jax.numpy as jnp
    if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
        return jnp.full_like(leaf, jnp.nan)
    return leaf


def poison_batches(source, at_iters: Iterable[int]):
    """Rewrite ``source.batches()`` so the device batch at each 0-based
    iteration in ``at_iters`` has every float leaf replaced by NaN —
    the deterministic NaN-at-step-k injection driving the engine's
    non-finite guard.  Applies to sources whose batches are array
    pytrees (every sampled source); a ``None`` batch (full-graph GD)
    passes through untouched.  Returns the source for chaining."""
    import jax
    at = set(int(i) for i in at_iters)
    orig = source.batches

    def batches():
        for i, (batch, n_nodes) in enumerate(orig()):
            if i in at and batch is not None:
                batch = jax.tree.map(_nanify, batch)
            yield batch, n_nodes

    source.batches = batches
    return source


# ---------------------------------------------------------------------------
# Seeded schedules
# ---------------------------------------------------------------------------

class FaultSchedule:
    """Deterministic chooser of *which* events to break: a fixed fault
    seed yields a fixed schedule, so every chaos test run injects the
    identical fault sequence (the acceptance criterion's "deterministic
    under a fixed fault seed")."""

    def __init__(self, seed: int):
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)

    def pick(self, n: int, k: int) -> Set[int]:
        """``k`` distinct event indices out of ``range(n)``."""
        k = min(int(k), int(n))
        return set(int(i) for i in
                   self._rng.choice(int(n), size=k, replace=False))

    def consecutive(self, n: int, k: int) -> Set[int]:
        """A run of ``k`` consecutive indices inside ``range(n)`` —
        e.g. k consecutive NaN steps to trip rollback escalation."""
        k = min(int(k), int(n))
        start = int(self._rng.integers(0, int(n) - k + 1))
        return set(range(start, start + k))
