"""Unified training engine: one Trainer, pluggable batch sources and
callbacks (the paper's central framing made executable: full-graph
training IS mini-batch training at the (b=n, beta=d_max) limit, so both
paradigms run through the SAME loop and differ only in their BatchSource).

Pieces
------
- ``BatchSource``     — where batches come from and how the loss is
  computed on one.  ``FullGraphSource`` (ELL layout, all train nodes)
  and ``SampledSource`` (vectorized CSR sampler, optional Prefetcher
  with reusable host staging buffers) are the paper's two paradigms.
- ``TrainPlan``       — declarative run spec: optimizer name/lr/schedule
  (resolved from ``repro.optim``), iteration budget, eval cadence,
  full-loss tracking, stop targets, checkpoint cadence.
- ``Callback``        — composable hooks (``on_step`` / ``on_eval`` /
  ``on_stop`` / ``on_train_start`` / ``on_train_end``).  History
  recording, early stopping and checkpointing are themselves callbacks.
- ``Trainer``         — the single loop.  ``train_full_graph`` /
  ``train_minibatch`` in ``core.trainer`` are thin wrappers over it and
  reproduce the pre-engine loss sequences bit-for-bit at fixed seed
  (test-enforced against recorded goldens).

``core.experiment`` builds the (b, beta) grid runner on top of this.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable as TCallable, List, Optional, Sequence, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.graph import Graph, to_ell
from repro.core.metrics import History
from repro.core.prefetch import HostStagingRing, Prefetcher
from repro.core.sampler import gather_features, sample_batch


# ---------------------------------------------------------------------------
# Shared device-side helpers (memoized per graph)
# ---------------------------------------------------------------------------

def _device_ell(graph: Graph, max_deg: Optional[int] = None):
    """Device-resident ELL layout, memoized per graph: evaluation and the
    full-loss tracker used to rebuild (re-pad + re-upload) it on every
    call.  The cache lives on the Graph instance so it dies with it."""
    key = int(max_deg or graph.d_max)
    cache = getattr(graph, "_ell_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(graph, "_ell_cache", cache)
    if "base" not in cache:                  # max_deg-independent uploads
        cache["base"] = (jnp.asarray(graph.feats),
                         jnp.asarray(graph.labels))
    if key not in cache:
        idx, w, w_self = to_ell(graph, max_deg=max_deg)
        cache[key] = (jnp.asarray(idx), jnp.asarray(w), jnp.asarray(w_self))
    return cache[key] + cache["base"]


def _device_nodes(graph: Graph, which: str):
    """Device copy of a node-id split (train/val/test), uploaded once per
    graph instead of per evaluation call."""
    cache = getattr(graph, "_node_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(graph, "_node_cache", cache)
    if which not in cache:
        cache[which] = jnp.asarray(getattr(graph, f"{which}_nodes"))
    return cache[which]


def evaluate_full(params, cfg: GNNConfig, graph: Graph, ell, nodes
                  ) -> float:
    """Inference uses ALL neighbors across the entire graph (§4.1)."""
    idx, w, w_self, feats, labels = ell
    logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self)
    sel = jnp.asarray(nodes)
    return float(G.accuracy(logits[sel], labels[sel]))


# ---------------------------------------------------------------------------
# TrainPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Declarative spec for one training run (what used to be ~10 loose
    keyword arguments spread over two loops)."""
    lr: float = 0.3
    n_iters: int = 100
    optimizer: str = "sgd"              # name in repro.optim: sgd | adamw
    momentum: float = 0.0               # sgd only
    weight_decay: float = 0.0           # adamw only
    schedule: Optional[str] = None      # None/"constant" | "cosine"
    warmup: int = 0                     # cosine warmup iters
    lr_floor: float = 0.0               # cosine floor
    eval_every: int = 10
    track_full_loss_every: int = 0      # mini-batch: full objective cadence
    target_loss: Optional[float] = None  # stop when batch loss <= target
    target_acc: Optional[float] = None   # stop when val acc >= target
    ckpt_every: int = 0
    ckpt_dir: str = "experiments/ckpt"
    seed: int = 0

    def make_schedule(self):
        if self.schedule in (None, "constant"):
            return self.lr
        if self.schedule == "cosine":
            from repro.optim import cosine_schedule
            return cosine_schedule(self.lr, self.warmup, self.n_iters,
                                   floor=self.lr_floor)
        raise ValueError(f"unknown schedule {self.schedule!r}")

    def make_optimizer(self):
        from repro.optim import adamw, sgd
        lr = self.make_schedule()
        if self.optimizer == "sgd":
            return sgd(lr, momentum=self.momentum)
        if self.optimizer == "adamw":
            return adamw(lr, weight_decay=self.weight_decay)
        raise ValueError(f"unknown optimizer {self.optimizer!r}; "
                         "repro.optim has: sgd, adamw")


# ---------------------------------------------------------------------------
# Batch sources
# ---------------------------------------------------------------------------

class BatchSource:
    """Where batches come from + how the training loss is computed on one.

    ``bind`` attaches graph/cfg/plan and uploads whatever is constant
    across iterations; ``batches`` yields ``(device_batch, n_nodes)``
    pairs; ``loss`` is traced inside the Trainer's single jitted step.
    ``done(batch)`` is called once the step consuming the batch has
    completed (host sync point) so sources may recycle staging buffers.
    """

    #: the per-iteration training loss already IS the full objective
    #: (true for full-graph GD; the History callback uses this).
    loss_is_full_loss = False
    name = "source"

    def bind(self, graph: Graph, cfg: GNNConfig, plan: TrainPlan
             ) -> "BatchSource":
        raise NotImplementedError

    def loss(self, params, batch):
        raise NotImplementedError

    def batches(self):
        raise NotImplementedError

    def done(self, batch) -> None:
        pass

    def close(self) -> None:
        pass


class FullGraphSource(BatchSource):
    """The (b=n_train, beta=d_max) limit: every iteration is GD over ALL
    training nodes on the device-resident ELL layout; the "batch" is
    empty because everything is constant across iterations."""

    loss_is_full_loss = True
    name = "fullgraph"

    def __init__(self, max_deg: Optional[int] = None):
        self.max_deg = max_deg

    def bind(self, graph, cfg, plan):
        self.graph, self.cfg = graph, cfg
        self.ell = _device_ell(graph, self.max_deg)
        self.train_nodes = _device_nodes(graph, "train")
        self.n_nodes = len(graph.train_nodes)
        return self

    def loss(self, params, batch):
        idx, w, w_self, feats, labels = self.ell
        logits = G.full_graph_forward(params, self.cfg, feats, idx, w,
                                      w_self)
        lt = logits[self.train_nodes]
        return G.gnn_loss(lt, labels[self.train_nodes], self.cfg.loss,
                          self.cfg.n_classes)

    def batches(self):
        while True:
            yield None, self.n_nodes


class SampledSource(BatchSource):
    """The paper's mini-batch paradigm: per-iteration (b, beta) fan-out
    trees from the vectorized CSR sampler, optionally produced ahead of
    the device step by a background ``Prefetcher`` thread.

    Device uploads go through a ``HostStagingRing``: host staging buffers
    are allocated ONCE per shape and recycled across batches (the ring
    slot is released in ``done`` once the consuming step has synced).
    Hop features are gathered DIRECTLY into the slot's buffers
    (``np.take(..., out=)``) and masks cast bool->f32 in place, so the
    plain path's fresh per-batch allocations disappear; with ``prefetch``
    that staging work runs on the Prefetcher's worker thread, off the
    device step's critical path.  The whole batch then ships as a single
    ``jax.device_put`` pytree transfer instead of ~4·n_layers separate
    ``jnp.asarray`` uploads."""

    name = "minibatch"

    def __init__(self, batch_size: Optional[int] = None,
                 fanouts: Optional[Sequence[int]] = None,
                 prefetch: bool = True, depth: int = 2,
                 reuse_buffers: bool = True):
        self.batch_size = batch_size
        self.fanouts = tuple(fanouts) if fanouts is not None else None
        self.prefetch = prefetch
        self.depth = depth
        self.reuse_buffers = reuse_buffers
        self._pf: Optional[Prefetcher] = None
        self._ring: Optional[HostStagingRing] = None
        self._inflight: List[int] = []   # staging slots awaiting done()

    def bind(self, graph, cfg, plan):
        self.graph, self.cfg = graph, cfg
        self.b = self.batch_size or cfg.batch_size
        self.fanouts = self.fanouts or tuple(cfg.fanout)
        assert len(self.fanouts) == cfg.n_layers
        self.n_iters = plan.n_iters
        self.seed = plan.seed
        self._inflight = []
        if self.reuse_buffers:
            # slots outnumber in-flight batches: queue depth + the batch
            # on the device + the one being staged on the worker
            self._ring = HostStagingRing(self.depth + 2)
        return self

    def loss(self, params, batch):
        feats, masks, weights, self_w, labels = batch
        logits = G.minibatch_forward(params, self.cfg, feats, masks,
                                     weights, self_w)
        return G.gnn_loss(logits, labels, self.cfg.loss,
                          self.cfg.n_classes)

    # -- host-side batch assembly --------------------------------------
    def _host_batch(self, graph, fb):
        """Host tuple for one batch.  Returns ``(slot, host_tree)`` —
        slot is -1 on the plain (no-ring) path.  Runs on the Prefetcher
        worker thread when prefetching."""
        if self._ring is None:
            feats = gather_features(graph, fb)
            masks = [m.astype(np.float32) for m in fb.masks]
            return -1, (feats, masks, fb.weights, fb.self_w, fb.labels)
        fd = graph.feats.shape[1]
        specs = ([(ids.shape + (fd,), graph.feats.dtype)
                  for ids in fb.nodes]
                 + [(m.shape, np.float32) for m in fb.masks]
                 + [(w.shape, w.dtype) for w in fb.weights]
                 + [(s.shape, s.dtype) for s in fb.self_w]
                 + [(fb.labels.shape, fb.labels.dtype)])
        slot = self._ring.acquire()
        bufs = iter(self._ring.buffers(slot, specs))
        feats = []
        for ids in fb.nodes:          # gather straight into the buffer
            buf = next(bufs)
            np.take(graph.feats, ids.reshape(-1), axis=0,
                    out=buf.reshape(-1, fd))
            feats.append(buf)
        masks = []
        for m in fb.masks:            # in-place bool -> f32 cast
            buf = next(bufs)
            np.copyto(buf, m, casting="unsafe")
            masks.append(buf)
        small = []
        for arrs in (fb.weights, fb.self_w):
            out = []
            for a in arrs:
                buf = next(bufs)
                np.copyto(buf, a)
                out.append(buf)
            small.append(out)
        labels = next(bufs)
        np.copyto(labels, fb.labels)
        return slot, (feats, masks, small[0], small[1], labels)

    def _to_device(self, payload):
        """One device_put for the whole batch; the ring slot joins an
        in-flight FIFO (batches complete in order) and is recycled by
        ``done`` once the consuming step has synced."""
        slot, host = payload
        if slot >= 0:
            self._inflight.append(slot)
        return jax.device_put(host)

    def batches(self):
        if self.prefetch:
            self._pf = Prefetcher(self.graph, self.b, self.fanouts,
                                  seed=self.seed, depth=self.depth,
                                  n_batches=self.n_iters,
                                  payload_fn=self._host_batch)
            try:
                for _ in range(self.n_iters):
                    fb, payload = self._pf.next()
                    yield self._to_device(payload), fb.batch_size
            finally:
                self.close()
        else:
            rng = np.random.default_rng(self.seed)
            for _ in range(self.n_iters):
                fb = sample_batch(rng, self.graph, self.b, self.fanouts)
                yield self._to_device(self._host_batch(self.graph, fb)), \
                    fb.batch_size

    def done(self, batch) -> None:
        if self._ring is not None and self._inflight:
            self._ring.release(self._inflight.pop(0))

    def close(self) -> None:
        if self._ring is not None:
            self._ring.close()     # wakes a worker blocked in acquire()
        if self._pf is not None:
            self._pf.close()
            self._pf = None


# ---------------------------------------------------------------------------
# Callbacks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainState:
    """Mutable loop state handed to every callback hook."""
    graph: Graph
    cfg: GNNConfig
    plan: TrainPlan
    source: BatchSource
    history: History
    it: int = -1                      # current iteration (0-based)
    params: Any = None
    opt_state: Any = None
    loss: float = float("nan")        # this iteration's training loss
    val_acc: Optional[float] = None   # this iteration's eval (None = none)
    n_nodes: int = 0                  # target nodes in this batch
    full_loss_fn: Optional[TCallable] = None   # params -> full objective
    stop: bool = False
    stop_reason: Optional[str] = None

    def request_stop(self, reason: str) -> None:
        if not self.stop:
            self.stop, self.stop_reason = True, reason


class Callback:
    """Hooks fire in list order; ``on_eval`` only on eval iterations,
    ``on_stop`` once when any callback requested a stop."""

    def on_train_start(self, state: TrainState) -> None: ...

    def on_step(self, state: TrainState) -> None: ...

    def on_eval(self, state: TrainState) -> None: ...

    def on_stop(self, state: TrainState) -> None: ...

    def on_train_end(self, state: TrainState) -> None: ...


class HistoryCallback(Callback):
    """Absorbs the loops' metric recording: per-iteration History rows
    plus full-objective tracking (every iteration for full-graph GD,
    every ``track_full_loss_every`` iterations for mini-batch)."""

    def on_train_start(self, state):
        state.history.start()

    def on_step(self, state):
        state.history.record(state.loss, state.val_acc,
                             nodes=state.n_nodes)
        if state.source.loss_is_full_loss:
            # full-graph training: the per-iteration loss IS the full loss
            state.history.full_losses.append(state.loss)
            state.history.full_loss_iters.append(state.it + 1)
        elif (state.plan.track_full_loss_every
              and state.it % state.plan.track_full_loss_every == 0):
            state.history.full_losses.append(
                float(state.full_loss_fn(state.params)))
            state.history.full_loss_iters.append(state.it + 1)


class EarlyStop(Callback):
    """The loops' stop rules: batch loss <= target_loss (checked every
    step, AFTER recording — the crossing iteration stays in History) and
    val acc >= target_acc (checked on eval iterations)."""

    def on_step(self, state):
        tl = state.plan.target_loss
        if tl is not None and state.loss <= tl:
            state.request_stop(f"target_loss<={tl}")

    def on_eval(self, state):
        ta = state.plan.target_acc
        if ta is not None and state.val_acc is not None \
                and state.val_acc >= ta:
            state.request_stop(f"target_acc>={ta}")


class CheckpointCallback(Callback):
    """Periodic params checkpointing via ``repro.checkpoint`` (same
    cadence semantics as launch/train.py's LM loop: skips step 0)."""

    def on_step(self, state):
        every = state.plan.ckpt_every
        if every and state.it and state.it % every == 0:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(state.plan.ckpt_dir, state.it, state.params,
                            {"loss": state.loss, "it": state.it,
                             "source": state.source.name})

    def on_train_end(self, state):
        if state.plan.ckpt_every:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(state.plan.ckpt_dir, state.it, state.params,
                            {"loss": state.loss, "it": state.it,
                             "source": state.source.name, "final": True})


def default_callbacks(plan: TrainPlan) -> List[Callback]:
    cbs: List[Callback] = [HistoryCallback(), EarlyStop()]
    if plan.ckpt_every:
        cbs.append(CheckpointCallback())
    return cbs


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainResult:
    params: list
    history: History
    final_test_acc: float
    stop_reason: Optional[str] = None


class Trainer:
    """The single training engine both paradigms run through.

    Per iteration: jitted step (value_and_grad over ``source.loss`` +
    optimizer update) -> periodic full-neighborhood eval -> ``on_step``
    callbacks (History / early-stop / checkpoint) -> ``on_eval`` on eval
    iterations -> break when any callback requested a stop.
    """

    def __init__(self, graph: Graph, cfg: GNNConfig, plan: TrainPlan,
                 source: Optional[BatchSource] = None,
                 callbacks: Optional[Sequence[Callback]] = None,
                 extra_callbacks: Sequence[Callback] = ()):
        self.graph, self.cfg, self.plan = graph, cfg, plan
        self.source = (source or SampledSource()).bind(graph, cfg, plan)
        self.callbacks = (list(callbacks) if callbacks is not None
                          else default_callbacks(plan))
        self.callbacks += list(extra_callbacks)
        self.opt = plan.make_optimizer()
        # evaluation + full-loss tracking reuse the source's ELL when it
        # has one (FullGraphSource with max_deg: eval on the SAME capped
        # adjacency the old loop used, and no second full-width upload)
        self._ell = getattr(self.source, "ell", None) or _device_ell(graph)

        src = self.source

        @jax.jit
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: src.loss(p, batch))(params)
            params, opt_state = self.opt.update(grads, opt_state, params)
            return params, opt_state, loss

        self._step = step

        idx_e, w_e, ws_e, feats_e, labels_e = self._ell
        train_sel = _device_nodes(graph, "train")

        @jax.jit
        def full_loss(params):
            logits = G.full_graph_forward(params, cfg, feats_e, idx_e,
                                          w_e, ws_e)
            return G.gnn_loss(logits[train_sel], labels_e[train_sel],
                              cfg.loss, cfg.n_classes)

        self._full_loss = full_loss

    # ------------------------------------------------------------------
    def evaluate(self, params, nodes) -> float:
        return evaluate_full(params, self.cfg, self.graph, self._ell,
                             nodes)

    def full_train_loss(self, params) -> float:
        return float(self._full_loss(params))

    def _fire(self, hook: str, state: TrainState) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(state)

    # ------------------------------------------------------------------
    def run(self) -> TrainResult:
        graph, cfg, plan = self.graph, self.cfg, self.plan
        key = jax.random.key(plan.seed)
        params = G.init_gnn(key, cfg, graph.feats.shape[1])
        opt_state = self.opt.init(params)

        state = TrainState(graph=graph, cfg=cfg, plan=plan,
                           source=self.source, history=History(),
                           params=params, opt_state=opt_state,
                           full_loss_fn=self._full_loss)
        self._fire("on_train_start", state)
        try:
            val_sel = _device_nodes(graph, "val")
            stream = self.source.batches()
            for it in range(plan.n_iters):
                batch, n_nodes = next(stream)
                params, opt_state, loss = self._step(params, opt_state,
                                                     batch)
                val = (self.evaluate(params, val_sel)
                       if it % plan.eval_every == 0 else None)
                state.it, state.params, state.opt_state = it, params, \
                    opt_state
                state.loss = float(loss)       # host sync: step finished
                state.val_acc, state.n_nodes = val, n_nodes
                self.source.done(batch)        # staging slot recyclable
                self._fire("on_step", state)
                if val is not None:
                    self._fire("on_eval", state)
                if state.stop:
                    self._fire("on_stop", state)
                    break
        finally:
            self.source.close()
        acc = self.evaluate(params, _device_nodes(graph, "test"))
        state.params = params
        self._fire("on_train_end", state)
        return TrainResult(params, state.history, acc, state.stop_reason)
