"""Unified training engine: one Trainer, pluggable batch sources and
callbacks (the paper's central framing made executable: full-graph
training IS mini-batch training at the (b=n, beta=d_max) limit, so both
paradigms run through the SAME loop and differ only in their BatchSource).

Pieces
------
- ``BatchSource``     — where batches come from and how the loss is
  computed on one.  ``FullGraphSource`` (ELL layout, all train nodes),
  ``ShardedFullGraphSource`` (the same, rows laid out over the NODES
  axis of a local device mesh) and ``SampledSource`` (vectorized CSR
  sampler, optional Prefetcher with reusable host staging buffers) are
  the paper's two paradigms; ``ClusterSource`` (Cluster-GCN unions of
  BFS partitions, ``core.partition``), ``ImportanceSampledSource``
  (score-weighted targets + unbiasedness-preserving loss reweighting)
  and ``ShardedSampledSource`` (the mini-batch twin of the sharded
  full-graph source) extend the space to the related-work scenarios.
- ``TrainPlan``       — declarative run spec: optimizer name/lr/schedule
  (resolved from ``repro.optim``), iteration budget, eval cadence,
  full-loss tracking, stop targets, checkpoint cadence, and the
  throughput knobs (``donate``, ``deferred_sync``).
- ``Callback``        — composable hooks (``on_step`` / ``on_eval`` /
  ``on_stop`` / ``on_train_start`` / ``on_train_end``).  History
  recording, early stopping and checkpointing are themselves callbacks.
- ``Trainer``         — the single loop.  ``train_full_graph`` /
  ``train_minibatch`` in ``core.trainer`` are thin wrappers over it and
  reproduce the pre-engine loss sequences bit-for-bit at fixed seed
  (test-enforced against recorded goldens).

Throughput path (docs/training_api.md "Throughput knobs"):

- the jitted step DONATES ``params``/``opt_state`` (and the sampled
  batch pytree), so the optimizer update reuses their device buffers
  instead of allocating fresh ones every iteration;
- the per-step ``float(loss)`` host sync is LAGGED one iteration
  (``plan.deferred_sync``): step ``i + 1`` is dispatched while step
  ``i`` is still in flight, and record ``i`` (loss / eval accuracy /
  tracked full loss, all device scalars) is read back afterwards.
  Staging-ring slots therefore recycle one step late and the ring grows
  by one slot.  Runs with stop targets or checkpoint cadence fall back
  to the synchronous read (their semantics need the loss on host
  immediately);
- compiled steps are CACHED per graph across Trainer instances (keyed
  by source type, normalized config, optimizer spec and the identity of
  the device constants), so a ``sweep()`` grid point with the same
  effective shapes never re-traces; partial batches are padded up to
  the plan's batch size with masked-out rows so each grid point
  compiles exactly one step function;
- evaluation and full-loss tracking run through module-level jitted
  functions keyed on a normalized config, shared across all Trainers of
  a sweep.

``core.experiment`` builds the (b, beta) grid runner on top of this.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Any, Callable as TCallable, List, Optional, Sequence, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.graph import Graph, to_ell
from repro.core.metrics import History
from repro.core.prefetch import HostStagingRing, Prefetcher
from repro.core.sampler import (FanoutBatch, expand_batch, gather_features,
                                sample_batch)


# ---------------------------------------------------------------------------
# Shared device-side helpers (memoized per graph)
# ---------------------------------------------------------------------------

def _resolve_max_deg(graph: Graph, max_deg: Optional[int]) -> int:
    """ELL width for an optional cap.  ``max_deg or graph.d_max`` is the
    trap this replaces: an explicit ``max_deg=0`` is falsy, so it used
    to silently fall back to the UNCAPPED d_max instead of erroring."""
    if max_deg is None:
        return graph.d_max
    if max_deg < 1:
        raise ValueError(f"max_deg must be >= 1 (or None for "
                         f"d_max={graph.d_max}), got {max_deg}")
    return int(max_deg)


def _device_ell(graph: Graph, max_deg: Optional[int] = None):
    """Device-resident ELL layout, memoized per graph: evaluation and the
    full-loss tracker used to rebuild (re-pad + re-upload) it on every
    call.  The cache lives on the Graph instance so it dies with it.

    At most ONE ELL key is resident besides the max_deg-independent
    "base" uploads: inserting a new key evicts the others, so a sweep
    over distinct ``max_deg`` values no longer accretes one full
    [n, K] upload per grid point (sources that need a capped ELL to
    outlive the cache hold their own reference via ``self.ell``).
    """
    key = _resolve_max_deg(graph, max_deg)
    cache = getattr(graph, "_ell_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(graph, "_ell_cache", cache)
    if "base" not in cache:                  # max_deg-independent uploads
        cache["base"] = (jnp.asarray(graph.feats),
                         jnp.asarray(graph.labels))
    if key not in cache:
        for stale in [k for k in cache if k != "base"]:
            del cache[stale]
        idx, w, w_self = to_ell(graph, max_deg=max_deg)
        cache[key] = (jnp.asarray(idx), jnp.asarray(w), jnp.asarray(w_self))
    return cache[key] + cache["base"]


def _device_nodes(graph: Graph, which: str):
    """Device copy of a node-id split (train/val/test), uploaded once per
    graph instead of per evaluation call."""
    cache = getattr(graph, "_node_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(graph, "_node_cache", cache)
    if which not in cache:
        cache[which] = jnp.asarray(getattr(graph, f"{which}_nodes"))
    return cache[which]


def _static_cfg(cfg: GNNConfig) -> GNNConfig:
    """Normalize the fields that do NOT affect the traced computation
    (names, sampler geometry) so the module-level jit caches — eval,
    full loss, compiled steps — are shared across sweep grid points."""
    return dataclasses.replace(
        cfg, name="", source="", batch_size=1,
        fanout=(1,) * cfg.n_layers, max_degree=1, n_nodes=0, feat_dim=0)


@functools.partial(jax.jit, static_argnums=(1, 8, 9))
def _eval_acc(params, cfg: GNNConfig, idx, w, w_self, feats, labels,
              nodes, mesh=None, feats_plan=None):
    # feats_plan (identity-hashed FeatShardPlan) rides as a STATIC arg:
    # it only steers tracing (featshard vs replicated kernel dispatch);
    # its device index arrays are closed over inside the op
    logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self,
                                  mesh=mesh, feats_plan=feats_plan)
    return G.accuracy(logits[nodes], labels[nodes])


def _graph_fn_cache(graph: Graph, key, build):
    """Per-graph compiled-function cache (dies with the graph): sweeps
    re-create Trainers per grid point, but grid points with the same
    effective shapes reuse ONE compiled step / full-loss function.

    ``key[-1]`` is the identity tuple of the device constants the
    function closes over; the entry holds those constants so the ids
    stay valid while it is alive.  Inserting an entry EVICTS entries
    for the same logical function with different (stale) constants —
    e.g. a sweep over distinct ``max_deg`` re-uploads the ELL per grid
    point, and without eviction each cached closure would pin a full
    upload on device (the accretion satellite #1 fixed in
    ``_device_ell`` would just move here).  A FIFO bound caps the rest.
    """
    cache = getattr(graph, "_fn_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(graph, "_fn_cache", cache)
    hit = cache.get(key)
    if hit is None:
        hit = build()
        for stale in [k for k in cache if k[:-1] == key[:-1]]:
            del cache[stale]
        while len(cache) >= 16:
            del cache[next(iter(cache))]
        cache[key] = hit
    return hit[0]


def _cached_full_loss(graph: Graph, cfg: GNNConfig, ell, sel, mesh=None,
                      feats_plan=None):
    """Full-training-objective loss (params -> device scalar), closure
    over the device ELL (closing over, instead of passing as arguments,
    keeps the pre-cache jaxpr and therefore the golden full-loss values
    bit-for-bit).  ``mesh`` (sharded sources with the kernel on)
    partitions the kernel's aggregation over the NODES axis;
    ``feats_plan`` additionally row-shards the source table
    (feats_layout="sharded")."""
    scfg = _static_cfg(cfg)
    key = ("full_loss", scfg, mesh,
           tuple(id(c) for c in ell) + (id(sel), id(feats_plan)))

    def build():
        idx, w, w_self, feats, labels = ell

        @jax.jit
        def full_loss(params):
            logits = G.full_graph_forward(params, scfg, feats, idx, w,
                                          w_self, mesh=mesh,
                                          feats_plan=feats_plan)
            return G.gnn_loss(logits[sel], labels[sel], scfg.loss,
                              scfg.n_classes)

        return full_loss, (ell, sel, feats_plan)

    return _graph_fn_cache(graph, key, build)


def evaluate_full(params, cfg: GNNConfig, graph: Graph, ell, nodes,
                  mesh=None, feats_plan=None) -> float:
    """Inference uses ALL neighbors across the entire graph (§4.1).
    Jitted once per (normalized config, shapes) at module level — NOT
    per Trainer — so sweeps stop paying eval retrace at every grid
    point."""
    idx, w, w_self, feats, labels = ell
    return float(_eval_acc(params, _static_cfg(cfg), idx, w, w_self,
                           feats, labels, jnp.asarray(nodes), mesh,
                           feats_plan))


# ---------------------------------------------------------------------------
# TrainPlan
# ---------------------------------------------------------------------------

class NonFiniteStepError(RuntimeError):
    """A jitted step produced a non-finite loss or gradient and the
    plan's ``BadStepPolicy`` escalated to raise."""

    def __init__(self, it: int, loss: float, consecutive: int):
        super().__init__(
            f"non-finite loss/gradients at iteration {it} "
            f"(loss={loss}, {consecutive} consecutive bad step"
            f"{'s' if consecutive != 1 else ''})")
        self.it = it
        self.loss = loss
        self.consecutive = consecutive


@dataclasses.dataclass(frozen=True)
class BadStepPolicy:
    """What the Trainer does when the in-step ``isfinite`` guard trips
    (docs/training_api.md "Fault tolerance" has the full matrix).

    The guard itself is always in the compiled step: a bad step leaves
    params/opt_state UNCHANGED on device (a ``where`` select), so by the
    time the host learns about it — one iteration late under
    ``deferred_sync`` — the next step has already run from the last good
    params with a fresh batch.  That makes ``"skip"`` exactly
    skip-and-resample, with no pipeline stall.

    - ``on_bad="raise"``: abort with ``NonFiniteStepError`` at the first
      bad step (the default: silent NaNs are how convergence curves lie).
    - ``on_bad="skip"``: tolerate up to ``max_consecutive`` bad steps in
      a row (History records them in ``bad_steps``), then ``escalate``
      ("raise", or "rollback" when checkpointing is on).
    - ``on_bad="rollback"``: skip until ``max_consecutive`` consecutive
      bad steps, then restore params/opt_state from the newest
      checkpoint and continue with fresh batches; more than
      ``max_rollbacks`` restores aborts.  Requires ``ckpt_every > 0``
      (validated at Trainer construction).
    """

    on_bad: str = "raise"            # raise | skip | rollback
    max_consecutive: int = 3         # skip/rollback escalation threshold
    escalate: str = "raise"          # skip's escalation: raise | rollback
    max_rollbacks: int = 3

    def __post_init__(self):
        if self.on_bad not in ("raise", "skip", "rollback"):
            raise ValueError(f"BadStepPolicy.on_bad must be raise|skip|"
                             f"rollback, got {self.on_bad!r}")
        if self.escalate not in ("raise", "rollback"):
            raise ValueError(f"BadStepPolicy.escalate must be raise|"
                             f"rollback, got {self.escalate!r}")
        if self.max_consecutive < 1:
            raise ValueError("BadStepPolicy.max_consecutive must be >= 1")

    def needs_ckpt(self) -> bool:
        return (self.on_bad == "rollback"
                or (self.on_bad == "skip" and self.escalate == "rollback"))


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Declarative spec for one training run (what used to be ~10 loose
    keyword arguments spread over two loops)."""
    lr: float = 0.3
    n_iters: int = 100
    optimizer: str = "sgd"              # name in repro.optim: sgd | adamw
    momentum: float = 0.0               # sgd only
    weight_decay: float = 0.0           # adamw only
    schedule: Optional[str] = None      # None/"constant" | "cosine"
    warmup: int = 0                     # cosine warmup iters
    lr_floor: float = 0.0               # cosine floor
    eval_every: int = 10
    track_full_loss_every: int = 0      # mini-batch: full objective cadence
    target_loss: Optional[float] = None  # stop when batch loss <= target
    target_acc: Optional[float] = None   # stop when val acc >= target
    ckpt_every: int = 0
    ckpt_dir: str = "experiments/ckpt"
    seed: int = 0
    # --- throughput knobs (docs/training_api.md) ---
    donate: bool = True                 # donate params/opt_state/batch
    deferred_sync: bool = True          # lag the float(loss) host sync
    # --- fault tolerance (docs/training_api.md "Fault tolerance") ---
    ckpt_keep_last: int = 0             # checkpoint retention (0 = all)
    bad_steps: BadStepPolicy = BadStepPolicy()

    def make_schedule(self):
        if self.schedule in (None, "constant"):
            return self.lr
        if self.schedule == "cosine":
            from repro.optim import cosine_schedule
            return cosine_schedule(self.lr, self.warmup, self.n_iters,
                                   floor=self.lr_floor)
        raise ValueError(f"unknown schedule {self.schedule!r}")

    def make_optimizer(self):
        from repro.optim import adamw, sgd
        lr = self.make_schedule()
        if self.optimizer == "sgd":
            return sgd(lr, momentum=self.momentum)
        if self.optimizer == "adamw":
            return adamw(lr, weight_decay=self.weight_decay)
        raise ValueError(f"unknown optimizer {self.optimizer!r}; "
                         "repro.optim has: sgd, adamw")


def _deferred_mode(plan: TrainPlan) -> bool:
    """Deferred loss sync needs the loss on host only one step late;
    stop targets and checkpoint cadence need it immediately."""
    return (plan.deferred_sync and plan.target_loss is None
            and plan.target_acc is None and plan.ckpt_every == 0)


def _opt_key(plan: TrainPlan) -> Tuple:
    """The subset of the plan the jitted step's optimizer depends on
    (n_iters only feeds the cosine schedule's horizon)."""
    return (plan.optimizer, plan.lr, plan.momentum, plan.weight_decay,
            plan.schedule, plan.warmup, plan.lr_floor,
            plan.n_iters if plan.schedule == "cosine" else 0)


def _guarded_update(opt, params, opt_state, loss, grads):
    """Optimizer update behind the non-finite step guard: a cheap
    ``isfinite`` reduction over loss + gradients is folded into the
    jitted step, and a bad step applies the IDENTITY update (``where``
    select keeps the old params/opt_state buffers bit-for-bit).  On a
    good step the select passes the new values through exactly, so the
    guard is value-invariant — the pre-PR-6 golden loss sequences are
    unchanged.  Returns (params, opt_state, good)."""
    good = jnp.isfinite(loss)
    for g in jax.tree.leaves(grads):
        good = good & jnp.all(jnp.isfinite(g))
    new_params, new_opt = opt.update(grads, opt_state, params)
    sel = lambda new, old: jnp.where(good, new, old)  # noqa: E731
    return (jax.tree.map(sel, new_params, params),
            jax.tree.map(sel, new_opt, opt_state), good)


def _cached_step(graph: Graph, src_cls: type, consts: Tuple,
                 cfg: GNNConfig, plan: TrainPlan):
    """Compiled train step, cached ON THE GRAPH across Trainer instances.

    The step closes over ``consts`` (e.g. the ELL tuple — closing over
    them keeps the pre-cache jaxprs, and therefore the golden loss
    sequences, bit-for-bit) so the cache key is (source type, normalized
    config, optimizer spec, donation flag, consts identity).  Because
    ``_device_ell`` memoizes the device uploads per graph, every grid
    point of a ``sweep()`` with the same effective shapes hits the same
    compiled step instead of re-tracing.
    """
    scfg = _static_cfg(cfg)
    key = ("step", src_cls.__qualname__, scfg, _opt_key(plan),
           plan.donate, tuple(id(c) for c in consts))

    def build():
        opt = plan.make_optimizer()

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: src_cls._loss_impl(p, batch, consts, scfg)
            )(params)
            params, opt_state, good = _guarded_update(
                opt, params, opt_state, loss, grads)
            return params, opt_state, loss, good

        fn = jax.jit(step,
                     donate_argnums=(0, 1, 2) if plan.donate else ())
        return fn, consts

    return _graph_fn_cache(graph, key, build)


# ---------------------------------------------------------------------------
# Batch sources
# ---------------------------------------------------------------------------

class BatchSource:
    """Where batches come from + how the training loss is computed on one.

    ``bind`` attaches graph/cfg/plan and uploads whatever is constant
    across iterations; ``batches`` yields ``(device_batch, n_nodes)``
    pairs; ``loss`` is traced inside the Trainer's single jitted step.
    ``done(batch)`` is called once the step consuming the batch has
    completed (host sync point) so sources may recycle staging buffers.
    ``close()`` is idempotent — the Trainer calls it from a ``finally``
    and early-stopping callbacks may have raced it already.

    Built-in sources additionally provide the *cacheable* loss form —
    a ``_loss_impl(params, batch, consts, cfg)`` staticmethod plus
    ``loss_consts()`` — which lets the engine reuse one compiled step
    across Trainer instances.  Custom sources only need ``loss``; they
    fall back to a per-Trainer jit.
    """

    #: the per-iteration training loss already IS the full objective
    #: (true for full-graph GD; the History callback uses this).
    loss_is_full_loss = False
    name = "source"
    #: cacheable loss form; None → per-Trainer jit fallback
    _loss_impl: Optional[TCallable] = None

    def bind(self, graph: Graph, cfg: GNNConfig, plan: TrainPlan
             ) -> "BatchSource":
        raise NotImplementedError

    def loss(self, params, batch):
        raise NotImplementedError

    def loss_consts(self) -> Tuple:
        """Device constants closed over by the cached step."""
        return ()

    def node_split(self, which: str):
        """Device array of a train/val/test node split, laid out however
        this source's forward expects (sharded sources replicate)."""
        return _device_nodes(self.graph, which)

    def place(self, tree):
        """Device placement for the params/opt_state pytrees before the
        first step.  Sharded sources replicate over their mesh so the
        step's input shardings are already final at iteration 0 —
        otherwise the first step's committed outputs silently force a
        SECOND compile at iteration 1."""
        return tree

    def batches(self):
        raise NotImplementedError

    def done(self, batch) -> None:
        pass

    def close(self) -> None:
        pass

    # -- exact-resume hooks --------------------------------------------
    def state_dict(self) -> dict:
        """JSON-serializable batch-stream position, saved inside every
        TrainerState checkpoint (sampled sources: consumed count + the
        rng bit-generator state after the last consumed draw).  Sources
        whose batches are constant across iterations have none."""
        return {}

    def load_state_dict(self, sd: dict) -> None:
        """Restore the stream position saved by ``state_dict`` (called
        between ``bind`` and ``batches`` on resume)."""
        if sd:
            raise ValueError(f"{type(self).__name__} has no stream state "
                             f"to restore, got keys {sorted(sd)}")


class FullGraphSource(BatchSource):
    """The (b=n_train, beta=d_max) limit: every iteration is GD over ALL
    training nodes on the device-resident ELL layout; the "batch" is
    empty because everything is constant across iterations."""

    loss_is_full_loss = True
    name = "fullgraph"

    def __init__(self, max_deg: Optional[int] = None):
        self.max_deg = max_deg
        self.ell = None

    def bind(self, graph, cfg, plan):
        self.graph, self.cfg = graph, cfg
        self.ell = _device_ell(graph, self.max_deg)
        self.train_nodes = _device_nodes(graph, "train")
        self.n_nodes = len(graph.train_nodes)
        return self

    @staticmethod
    def _loss_impl(params, batch, consts, cfg: GNNConfig):
        idx, w, w_self, feats, labels, train_nodes = consts
        logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self)
        lt = logits[train_nodes]
        return G.gnn_loss(lt, labels[train_nodes], cfg.loss,
                          cfg.n_classes)

    def loss_consts(self):
        return tuple(self.ell) + (self.train_nodes,)

    def loss(self, params, batch):
        return type(self)._loss_impl(params, batch, self.loss_consts(),
                                     self.cfg)

    def batches(self):
        while True:
            yield None, self.n_nodes

    def close(self) -> None:
        # idempotent: drop the device ELL reference (the per-graph cache
        # keeps at most one resident key; sources release theirs here)
        self.ell = None


class ShardedFullGraphSource(FullGraphSource):
    """Full-graph GD with the ELL rows laid out over the ``NODES`` axis
    of a local device mesh (``NamedSharding`` row sharding), so the
    paper's (b=n, beta=d_max) limit runs data-parallel over all local
    devices — rows are padded with zero-weight entries up to a multiple
    of the mesh size, and the node splits are replicated so the same
    jitted eval/step functions serve every device.

    On a 1-device mesh this produces the exact same loss sequence as
    ``FullGraphSource`` (test-enforced); on an N-device mesh XLA GSPMD
    partitions the forward (the [n, K] gathers all-gather the layer
    activations) and all-reduces the gradients.  With
    ``cfg.use_agg_kernel`` the Pallas aggregation runs shard-locally
    over the same mesh (shard_map; ``kernels/README.md`` "Sharding") —
    bit-equal to the unsharded kernel on 1 device, einsum-equivalent on
    N.
    """

    name = "fullgraph_sharded"

    def __init__(self, max_deg: Optional[int] = None, mesh=None):
        super().__init__(max_deg)
        self.mesh = mesh

    def bind(self, graph, cfg, plan):
        from repro import sharding as sh
        self.graph, self.cfg = graph, cfg
        mesh = self.mesh if self.mesh is not None else sh.node_mesh()
        self._mesh = mesh
        n_dev = int(np.prod(list(mesh.shape.values())))
        # memoized per graph like _device_ell (same one-resident-key
        # eviction): a sweep over sharded grid points reuses ONE upload
        # and therefore ONE compiled step (the step cache keys on the
        # consts' identity)
        key = (tuple(d.id for d in mesh.devices.flat),
               _resolve_max_deg(graph, self.max_deg))
        cache = getattr(graph, "_sharded_ell_cache", None)
        if cache is None:
            cache = {}
            object.__setattr__(graph, "_sharded_ell_cache", cache)
        if key not in cache:
            cache.clear()
            idx, w, w_self = to_ell(graph, max_deg=self.max_deg)
            feats, labels = graph.feats, graph.labels
            pad = (-graph.n) % n_dev
            if pad:               # zero-weight rows aggregate to zero
                idx = np.pad(idx, ((0, pad), (0, 0)))
                w = np.pad(w, ((0, pad), (0, 0)))
                w_self = np.pad(w_self, (0, pad))
                feats = np.pad(feats, ((0, pad), (0, 0)))
                labels = np.pad(labels, (0, pad))
            rows2 = sh.named((sh.NODES, None), mesh)
            rows1 = sh.named((sh.NODES,), mesh)
            repl = sh.named((None,), mesh)
            ell = (jax.device_put(np.ascontiguousarray(idx), rows2),
                   jax.device_put(np.ascontiguousarray(w), rows2),
                   jax.device_put(np.ascontiguousarray(w_self), rows1),
                   jax.device_put(np.ascontiguousarray(feats), rows2),
                   jax.device_put(np.ascontiguousarray(labels), rows1))
            cache[key] = (ell, repl, {})
        self.ell, self._repl, self._splits = cache[key]
        self.feats_plan = None
        self.featshard_stats = None
        if cfg.feats_layout == "sharded" and cfg.use_agg_kernel:
            self.feats_plan = self._bind_featshard(graph, cfg, mesh, key,
                                                   n_dev)
        self.train_nodes = self.node_split("train")
        self.n_nodes = len(graph.train_nodes)
        return self

    def _bind_featshard(self, graph, cfg, mesh, key, n_dev):
        """Build (or reuse) the static featshard plan for this
        (ELL, mesh, C) and record the bind-time accounting the ISSUE's
        acceptance asserts on: per-device table bytes n·d/S + C·d and
        remote-gather bytes per aggregation call."""
        from repro.kernels.neighbor_agg.ops import build_featshard_plan
        pkey = key + (cfg.feat_cache_rows,)
        pcache = getattr(graph, "_featshard_plan_cache", None)
        if pcache is None:
            pcache = {}
            object.__setattr__(graph, "_featshard_plan_cache", pcache)
        if pkey not in pcache:
            # one-resident-key eviction like the ELL cache: cached steps
            # that closed over an evicted plan keep it alive themselves
            pcache.clear()
            idx_h, w_h, _ = to_ell(graph, max_deg=self.max_deg)
            pad = (-graph.n) % n_dev
            if pad:
                idx_h = np.pad(idx_h, ((0, pad), (0, 0)))
                w_h = np.pad(w_h, ((0, pad), (0, 0)))
            pcache[pkey] = build_featshard_plan(
                idx_h, w_h, graph.degrees, mesh,
                cache_rows=cfg.feat_cache_rows)
        fsplan = pcache[pkey]
        d = graph.feats.shape[1]
        item = 2 if cfg.dtype == "bfloat16" else graph.feats.dtype.itemsize
        st = dict(fsplan.stats)
        st["feat_table_bytes_per_device"] = \
            fsplan.table_bytes_per_device(d, item)
        st["feat_remote_gather_bytes"] = fsplan.remote_bytes_per_call(
            d, item)
        self.featshard_stats = st
        return fsplan

    @staticmethod
    def _loss_impl(params, batch, consts, cfg: GNNConfig):
        idx, w, w_self, feats, labels, train_nodes, mesh, fsplan = consts
        logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self,
                                      mesh=mesh, feats_plan=fsplan)
        lt = logits[train_nodes]
        return G.gnn_loss(lt, labels[train_nodes], cfg.loss,
                          cfg.n_classes)

    def loss_consts(self):
        # the mesh and featshard plan ride along as (static, closed-over)
        # consts so the forward can shard_map the kernel path over the
        # NODES axis; sh.node_mesh() and the per-graph plan cache are
        # memoized, keeping the step-cache key (which hashes the consts'
        # identity) stable across binds
        return tuple(self.ell) + (self.train_nodes, self._mesh,
                                  self.feats_plan)

    def node_split(self, which: str):
        if which not in self._splits:
            self._splits[which] = jax.device_put(
                getattr(self.graph, f"{which}_nodes"), self._repl)
        return self._splits[which]

    def place(self, tree):
        from repro import sharding as sh
        repl = sh.named((), self._mesh)          # P(): any-rank replicate
        return jax.tree.map(lambda a: jax.device_put(a, repl), tree)


class SampledSource(BatchSource):
    """The paper's mini-batch paradigm: per-iteration (b, beta) fan-out
    trees from the vectorized CSR sampler, optionally produced ahead of
    the device step by a background ``Prefetcher`` thread.

    Device uploads go through a ``HostStagingRing``: host staging buffers
    are allocated ONCE per shape and recycled across batches (the ring
    slot is released in ``done`` once the consuming step has synced; with
    the engine's deferred loss sync that release lags one extra step, so
    the ring grows by one slot).  Hop features are gathered DIRECTLY into
    the slot's buffers (``np.take(..., out=)``) and masks cast bool->f32
    in place, so the plain path's fresh per-batch allocations disappear;
    with ``prefetch`` that staging work runs on the Prefetcher's worker
    thread, off the device step's critical path.  The whole batch then
    ships as a single ``jax.device_put`` pytree transfer instead of
    ~4·n_layers separate ``jnp.asarray`` uploads.

    When the graph has fewer training nodes than the configured batch
    size, every batch is PADDED up to ``batch_size`` with masked-out
    rows (zero weights, zero labels, a validity column), so the grid
    point still compiles exactly one step function; the masked loss
    matches the unpadded mean up to float summation order."""

    name = "minibatch"

    def __init__(self, batch_size: Optional[int] = None,
                 fanouts: Optional[Sequence[int]] = None,
                 prefetch: bool = True, depth: int = 2,
                 reuse_buffers: bool = True):
        self.batch_size = batch_size
        self.fanouts = tuple(fanouts) if fanouts is not None else None
        self.prefetch = prefetch
        self.depth = depth
        self.reuse_buffers = reuse_buffers
        self._pf: Optional[Prefetcher] = None
        self._ring: Optional[HostStagingRing] = None
        self._inflight: List[int] = []   # staging slots awaiting done()
        self._consumed = 0               # batches delivered so far
        self._last_rng_state = None      # rng state after last delivery
        self._resume_rng_state = None    # restored position (resume)

    def bind(self, graph, cfg, plan):
        self.graph, self.cfg = graph, cfg
        self._consumed = 0
        self._last_rng_state = None
        self._resume_rng_state = None
        n_train = len(graph.train_nodes)
        if n_train == 0:
            raise ValueError(
                f"{type(self).__name__}: graph has no training nodes "
                f"(train_mask selects 0 of {graph.n}) — nothing to sample")
        # b_request is what the sampler draws; b is the fixed compiled
        # width every batch pads up to (subclasses may round b up, e.g.
        # to a mesh-size multiple, without over-sampling targets)
        self.b_request = self.b = self.batch_size or cfg.batch_size
        if self.b < 1:
            raise ValueError(f"{type(self).__name__}: batch_size must be "
                             f">= 1, got {self.b}")
        self.fanouts = self.fanouts or tuple(cfg.fanout)
        assert len(self.fanouts) == cfg.n_layers
        self.n_iters = plan.n_iters
        self.seed = plan.seed
        self.pad = max(0, self.b - n_train)
        self._inflight = []
        if self.reuse_buffers:
            # slots outnumber in-flight batches: queue depth + the batch
            # on the device + the one being staged on the worker (+ one
            # more when the engine recycles a step late under deferred
            # loss sync)
            extra = 1 if _deferred_mode(plan) else 0
            self._ring = HostStagingRing(self.depth + 2 + extra)
        return self

    @staticmethod
    def _loss_impl(params, batch, consts, cfg: GNNConfig):
        if len(batch) == 6:              # padded batch: masked mean
            feats, masks, weights, self_w, labels, valid = batch
        else:
            feats, masks, weights, self_w, labels = batch
            valid = None
        logits = G.minibatch_forward(params, cfg, feats, masks, weights,
                                     self_w)
        return G.gnn_loss(logits, labels, cfg.loss, cfg.n_classes,
                          valid=valid)

    def loss(self, params, batch):
        return type(self)._loss_impl(params, batch, self.loss_consts(),
                                     self.cfg)

    # -- host-side batch assembly --------------------------------------
    def _pad_batch(self, fb: FanoutBatch) -> FanoutBatch:
        """Pad the target-node axis up to ``self.b`` with masked-out rows
        so every batch of this grid point has ONE compiled shape."""
        p = self.b - fb.batch_size
        if p <= 0:
            return fb

        def padrow(a):
            return np.pad(a, [(0, p)] + [(0, 0)] * (a.ndim - 1))

        return FanoutBatch(
            nodes=[padrow(x) for x in fb.nodes],
            masks=[padrow(m) for m in fb.masks],
            weights=[padrow(w) for w in fb.weights],
            self_w=[padrow(s) for s in fb.self_w],
            labels=padrow(fb.labels),
            target_w=(padrow(fb.target_w)
                      if fb.target_w is not None else None))

    # -- subclass hooks ------------------------------------------------
    def _sample(self, rng, graph, batch_size, fanouts) -> FanoutBatch:
        """How one batch is drawn (Prefetcher-compatible signature).
        Subclasses override for non-uniform target selection."""
        return sample_batch(rng, graph, batch_size, fanouts)

    def _extra_cols(self, fb: FanoutBatch, valid_n: int) -> Tuple:
        """Columns appended after ``labels`` in the host batch tuple
        (``_loss_impl`` must unpack in the same order)."""
        if not self.pad:
            return ()
        valid = np.zeros(self.b, np.float32)
        valid[:valid_n] = 1.0
        return (valid,)

    def _host_batch(self, graph, fb):
        """Host tuple for one batch.  Returns ``(slot, host_tree)`` —
        slot is -1 on the plain (no-ring) path.  Runs on the Prefetcher
        worker thread when prefetching."""
        valid_n = fb.batch_size
        fb = self._pad_batch(fb)
        extra: Tuple = tuple(self._extra_cols(fb, valid_n))
        if self._ring is None:
            feats = gather_features(graph, fb)
            masks = [m.astype(np.float32) for m in fb.masks]
            return -1, (feats, masks, fb.weights, fb.self_w,
                        fb.labels) + extra
        fd = graph.feats.shape[1]
        specs = ([(ids.shape + (fd,), graph.feats.dtype)
                  for ids in fb.nodes]
                 + [(m.shape, np.float32) for m in fb.masks]
                 + [(w.shape, w.dtype) for w in fb.weights]
                 + [(s.shape, s.dtype) for s in fb.self_w]
                 + [(fb.labels.shape, fb.labels.dtype)]
                 + [(v.shape, v.dtype) for v in extra])
        slot = self._ring.acquire()
        try:
            bufs = iter(self._ring.buffers(slot, specs))
            feats = []
            for ids in fb.nodes:      # gather straight into the buffer
                buf = next(bufs)
                np.take(graph.feats, ids.reshape(-1), axis=0,
                        out=buf.reshape(-1, fd))
                feats.append(buf)
            masks = []
            for m in fb.masks:        # in-place bool -> f32 cast
                buf = next(bufs)
                np.copyto(buf, m, casting="unsafe")
                masks.append(buf)
            small = []
            for arrs in (fb.weights, fb.self_w):
                out = []
                for a in arrs:
                    buf = next(bufs)
                    np.copyto(buf, a)
                    out.append(buf)
                small.append(out)
            labels = next(bufs)
            np.copyto(labels, fb.labels)
            tail = []
            for v in extra:
                buf = next(bufs)
                np.copyto(buf, v)
                tail.append(buf)
        except BaseException:
            # a worker dying mid-batch must not strand its staging slot:
            # the consuming step never runs, so done() would never
            # release it and the ring would leak one slot per failure
            self._ring.release(slot)
            raise
        return slot, (feats, masks, small[0], small[1], labels) \
            + tuple(tail)

    def _to_device(self, payload):
        """One device_put for the whole batch; the ring slot joins an
        in-flight FIFO (batches complete in order) and is recycled by
        ``done`` once the consuming step has synced."""
        slot, host = payload
        if slot >= 0:
            self._inflight.append(slot)
        return jax.device_put(host)

    def state_dict(self):
        return {"consumed": self._consumed,
                "rng_state": self._last_rng_state}

    def load_state_dict(self, sd):
        if not sd:
            return
        self._consumed = int(sd["consumed"])
        self._resume_rng_state = sd.get("rng_state")
        if self._consumed and self._resume_rng_state is None:
            raise ValueError(
                f"{type(self).__name__}: checkpoint records "
                f"{self._consumed} consumed batches but no rng state — "
                f"cannot resume the stream exactly")

    def batches(self):
        # resume-aware: a restored stream starts at batch `_consumed`
        # with the rng fast-forwarded to the checkpointed state, so the
        # sequence continues bit-for-bit where the checkpoint left off
        remaining = self.n_iters - self._consumed
        if self.prefetch:
            self._pf = Prefetcher(self.graph, self.b_request, self.fanouts,
                                  seed=self.seed, depth=self.depth,
                                  n_batches=remaining,
                                  payload_fn=self._host_batch,
                                  sample_fn=self._sample,
                                  rng_state=self._resume_rng_state)
            try:
                for _ in range(remaining):
                    fb, payload = self._pf.next()
                    self._last_rng_state = self._pf.last_rng_state
                    self._consumed += 1
                    yield self._to_device(payload), fb.batch_size
            finally:
                self.close()
        else:
            rng = np.random.default_rng(self.seed)
            if self._resume_rng_state is not None:
                rng.bit_generator.state = self._resume_rng_state
            for _ in range(remaining):
                fb = self._sample(rng, self.graph, self.b_request,
                                  self.fanouts)
                self._last_rng_state = rng.bit_generator.state
                self._consumed += 1
                yield self._to_device(self._host_batch(self.graph, fb)), \
                    fb.batch_size

    def done(self, batch) -> None:
        if self._ring is not None and self._inflight:
            self._ring.release(self._inflight.pop(0))

    def close(self) -> None:
        # idempotent: an early-stopping callback and the Trainer's
        # finally may both land here without racing the worker thread
        if self._ring is not None:
            self._ring.close()     # wakes a worker blocked in acquire()
        if self._pf is not None:
            pf, self._pf = self._pf, None
            pf.close()


class ImportanceSampledSource(SampledSource):
    """Mini-batch SGD with NON-uniform target selection: targets are
    drawn WITH replacement from the training split with probability
    p_j ∝ score_j, and every sampled row carries the loss weight
    w_j = 1 / (n_train · p_j), so the weighted batch mean stays an
    UNBIASED estimator of the full training objective
    (E[1/b Σ w_j ℓ_j] = 1/n Σ ℓ_i) no matter how skewed — or how far
    from summing to one — the scores are.

    ``scores`` selects the proposal ("The Case for Sampling", Serafini
    & Guan 2021 — sampling design changes both convergence and cost):

    - ``"degree"`` (default): (deg + 1) ** alpha — high-degree nodes,
      whose fan-out trees are the expensive ones, are visited more
      often but down-weighted accordingly;
    - ``"grad"``: per-node gradient norm ‖∂ℓ_i/∂logits_i‖ at the
      plan-seed init params (one full-graph forward at bind time) — a
      cheap static proxy for gradient-norm importance sampling;
    - an array of per-node (length n) or per-train-node (length
      n_train) non-negative scores — e.g. gradient norms refreshed from
      a pilot run.  Zero scores are floored to a tiny positive value:
      a node with p_j = 0 would never be sampled and the estimator
      would silently drop its loss term.

    Sampling WITH replacement means any ``batch_size`` is valid —
    b > n_train never pads, it just revisits nodes (weights keep the
    estimator honest).  Everything else (Prefetcher, HostStagingRing,
    pad/donate/deferred-sync fast path) is inherited from
    ``SampledSource``.
    """

    name = "importance"

    def __init__(self, batch_size: Optional[int] = None,
                 fanouts: Optional[Sequence[int]] = None,
                 scores="degree", alpha: float = 1.0, **kw):
        super().__init__(batch_size, fanouts, **kw)
        self.scores = scores
        self.alpha = alpha

    def bind(self, graph, cfg, plan):
        super().bind(graph, cfg, plan)
        train = graph.train_nodes
        if isinstance(self.scores, str):
            if self.scores == "degree":
                s = (graph.degrees[train] + 1.0) ** self.alpha
            elif self.scores == "uniform":
                s = np.ones(len(train), np.float64)
            elif self.scores == "grad":
                s = self._grad_norm_scores(graph, cfg, plan)
            else:
                raise ValueError(
                    f"ImportanceSampledSource: unknown scores mode "
                    f"{self.scores!r} (have: degree, uniform, grad, or an "
                    f"array)")
        else:
            s = np.asarray(self.scores, np.float64).reshape(-1)
            if s.shape[0] == graph.n:
                s = s[train]
            if s.shape[0] != len(train):
                raise ValueError(
                    f"ImportanceSampledSource: scores must have length "
                    f"n={graph.n} or n_train={len(train)}, got "
                    f"{s.shape[0]}")
        if not np.all(np.isfinite(s)) or (s < 0).any() or s.sum() <= 0:
            raise ValueError(
                "ImportanceSampledSource: scores must be finite, "
                "non-negative, with a positive sum")
        if (s == 0).any():              # p_j = 0 would bias the estimator
            s = np.where(s > 0, s, s[s > 0].min() * 1e-6)
        p = s / s.sum()
        self._p = p
        self._train = train
        # E_p[w] = Σ p_j / (n p_j) = 1: uniform scores give weight 1.0
        self._w = (1.0 / (len(train) * p)).astype(np.float32)
        # replacement always fills b_request rows, so padding exists
        # only when a subclass rounds the compiled width up (the valid
        # column below masks those rows)
        self.pad = self.b - self.b_request
        return self

    def _grad_norm_scores(self, graph, cfg, plan):
        """‖∂ℓ_i/∂logits_i‖ per train node at the plan-seed init params
        (softmax(z) − onehot for CE, z − onehot for MSE)."""
        idx, w, w_self, feats, labels = _device_ell(graph)
        params = G.init_gnn(jax.random.key(plan.seed), cfg,
                            graph.feats.shape[1])
        logits = np.asarray(G.full_graph_forward(
            params, _static_cfg(cfg), feats, idx, w, w_self))
        tr = graph.train_nodes
        lt = logits[tr].astype(np.float64)
        onehot = np.zeros_like(lt)
        onehot[np.arange(len(tr)), graph.labels[tr]] = 1.0
        if cfg.loss == "mse":
            g = lt - onehot
        else:
            e = np.exp(lt - lt.max(axis=1, keepdims=True))
            g = e / e.sum(axis=1, keepdims=True) - onehot
        return np.linalg.norm(g, axis=1)

    def _sample(self, rng, graph, batch_size, fanouts):
        # batch_size is b_request per the hook contract — a subclass
        # that rounds self.b up must not over-sample targets
        sel = rng.choice(len(self._train), size=batch_size, replace=True,
                         p=self._p)
        fb = expand_batch(rng, graph,
                          self._train[sel].astype(np.int32), fanouts)
        fb.target_w = self._w[sel]
        return fb

    def _extra_cols(self, fb, valid_n):
        valid = np.zeros(self.b, np.float32)
        valid[:valid_n] = 1.0
        return (valid, fb.target_w)

    @staticmethod
    def _loss_impl(params, batch, consts, cfg: GNNConfig):
        feats, masks, weights, self_w, labels, valid, row_w = batch
        logits = G.minibatch_forward(params, cfg, feats, masks, weights,
                                     self_w)
        return G.gnn_loss(logits, labels, cfg.loss, cfg.n_classes,
                          valid=valid, weight=row_w)


class ShardedSampledSource(SampledSource):
    """Data-parallel mini-batches: the sampled batch's target axis is
    laid out over the ``NODES`` axis of a local device mesh — the
    mini-batch twin of ``ShardedFullGraphSource``.  The host side is
    inherited unchanged (CSR sampler, Prefetcher, per-shape
    ``HostStagingRing``); only the upload differs: every leaf of the
    batch pytree is ``device_put`` with a NODES-sharded leading axis
    (``sharding.row_sharding``), so XLA GSPMD partitions the fan-out
    tree forward per device shard and all-reduces the gradients.

    ``b`` is rounded UP to a multiple of the mesh size; the surplus
    rows ride the engine's existing masked-row padding (the valid
    column keeps the loss equal to the unpadded mean).  On a 1-device
    mesh the host batches, the compiled step, and therefore the loss
    sequence are identical to ``SampledSource`` (test-enforced
    bit-for-bit).  With ``cfg.use_agg_kernel`` each shard runs the
    tiled Pallas kernel on its local rows of the fan-out tree
    (collective-free — the gather table derives from the row-sharded
    batch).
    """

    name = "minibatch_sharded"

    def __init__(self, batch_size: Optional[int] = None,
                 fanouts: Optional[Sequence[int]] = None, mesh=None, **kw):
        super().__init__(batch_size, fanouts, **kw)
        self.mesh = mesh

    def bind(self, graph, cfg, plan):
        from repro import sharding as sh
        super().bind(graph, cfg, plan)
        mesh = self.mesh if self.mesh is not None else sh.node_mesh()
        self._mesh = mesh
        n_dev = int(np.prod(list(mesh.shape.values())))
        if self.b % n_dev:               # surplus rows are masked out
            self.b += (-self.b) % n_dev
        self.pad = max(0, self.b - min(self.b_request,
                                       len(graph.train_nodes)))
        self._repl = sh.named((None,), mesh)
        self._row_shardings: dict = {}
        self._repl_splits: dict = {}
        # feats_layout="sharded": sampled fan-outs change every step, so
        # the hot set is the LRU variant — a host-side cache model over
        # the per-batch source-node ids (counted on the Prefetcher
        # worker, surfaced through History.counters / bench columns)
        self.feat_cache = None
        if cfg.feats_layout == "sharded":
            from repro.core.featcache import (LRURowCache,
                                              resolve_cache_rows)
            self.feat_cache = LRURowCache(
                resolve_cache_rows(cfg.feat_cache_rows, graph.n),
                row_bytes=graph.feats.shape[1]
                * graph.feats.dtype.itemsize)
        return self

    def _host_batch(self, graph, fb):
        if self.feat_cache is not None:
            # single-threaded by construction: one Prefetcher worker (or
            # inline when prefetch is off) stages every batch in order
            for ids in fb.nodes:
                self.feat_cache.lookup(ids.reshape(-1))
        return super()._host_batch(graph, fb)

    @staticmethod
    def _loss_impl(params, batch, consts, cfg: GNNConfig):
        (mesh,) = consts
        if len(batch) == 6:              # padded batch: masked mean
            feats, masks, weights, self_w, labels, valid = batch
        else:
            feats, masks, weights, self_w, labels = batch
            valid = None
        logits = G.minibatch_forward(params, cfg, feats, masks, weights,
                                     self_w, mesh=mesh)
        return G.gnn_loss(logits, labels, cfg.loss, cfg.n_classes,
                          valid=valid)

    def loss_consts(self):
        # static closed-over mesh for the shard_map'd kernel path (the
        # memoized sh.node_mesh keeps the step-cache key stable)
        return (self._mesh,)

    def _row_sharding(self, ndim: int):
        from repro import sharding as sh
        s = self._row_shardings.get(ndim)
        if s is None:
            s = sh.row_sharding(self._mesh, ndim)
            self._row_shardings[ndim] = s
        return s

    def _to_device(self, payload):
        slot, host = payload
        if slot >= 0:
            self._inflight.append(slot)
        return jax.device_put(
            host, jax.tree.map(lambda a: self._row_sharding(a.ndim), host))

    def node_split(self, which: str):
        # replicated over the mesh so eval mixes cleanly with the
        # mesh-committed params the sharded step produces
        if which not in self._repl_splits:
            self._repl_splits[which] = jax.device_put(
                getattr(self.graph, f"{which}_nodes"), self._repl)
        return self._repl_splits[which]

    def place(self, tree):
        from repro import sharding as sh
        repl = sh.named((), self._mesh)          # P(): any-rank replicate
        return jax.tree.map(lambda a: jax.device_put(a, repl), tree)


class ClusterSource(BatchSource):
    """Cluster-GCN style batching: partition once (greedy BFS,
    ``core.partition`` — no METIS dependency), then every iteration
    trains on the induced subgraph of a union of k clusters.  Against
    node-wise (b, β) fan-out sampling this trades neighbor explosion
    for a bounded, reusable batch structure: each cluster's induced ELL
    block is built ONCE at bind and batches assemble block-diagonally
    (cross-cluster edges are dropped — vanilla Cluster-GCN's documented
    approximation).

    The batch is a fixed-shape padded ELL ([m_max, K] with m_max = the
    k largest clusters stacked, K = the widest induced block), so every
    grid point compiles exactly ONE step like the other sources, and
    donation/deferred-sync apply unchanged.  The loss runs the
    FULL-GRAPH forward on the batch-local ELL and masks to the batch's
    training rows (padding and non-train rows carry zero ``valid``).
    Batches with zero training rows are rejection-resampled (bind
    fails fast if NO cluster contains a training node).
    """

    name = "cluster"

    def __init__(self, batch_size: Optional[int] = None,
                 clusters_per_batch: int = 2,
                 n_parts: Optional[int] = None, partition_seed: int = 0):
        if clusters_per_batch < 1:
            raise ValueError(f"ClusterSource: clusters_per_batch must be "
                             f">= 1, got {clusters_per_batch}")
        if n_parts is not None and n_parts < 1:
            raise ValueError(f"ClusterSource: n_parts must be >= 1, got "
                             f"{n_parts}")
        self.batch_size = batch_size
        self.clusters_per_batch = clusters_per_batch
        self.n_parts = n_parts
        self.partition_seed = partition_seed
        self._pf: Optional[Prefetcher] = None

    def bind(self, graph, cfg, plan):
        from repro.core.partition import bfs_partition, cluster_ell_blocks
        self.graph, self.cfg = graph, cfg
        self.b = self.batch_size or cfg.batch_size
        k = self.clusters_per_batch
        if self.n_parts is None:
            # expected union size ≈ b: n/P nodes per cluster, k per batch
            n_parts = int(round(graph.n * k / max(self.b, 1)))
        else:
            n_parts = self.n_parts
        n_parts = min(max(n_parts, k), graph.n)
        part = bfs_partition(graph, n_parts, seed=self.partition_seed)
        blocks = cluster_ell_blocks(graph, part)
        self.blocks = blocks
        self.n_parts_ = len(blocks.clusters)
        self.k = min(k, self.n_parts_)
        self._train_valid = [graph.train_mask[c].astype(np.float32)
                             for c in blocks.clusters]
        self._has_train = np.array([v.sum() > 0 for v in self._train_valid])
        if not self._has_train.any():
            raise ValueError(
                "ClusterSource: no cluster contains a training node "
                f"(n_train={len(graph.train_nodes)}) — nothing to train on")
        sizes = blocks.sizes
        self.m_max = int(np.sort(sizes)[::-1][:self.k].sum())
        self.K = blocks.max_width
        self._feats = [graph.feats[c] for c in blocks.clusters]
        self._labels = [graph.labels[c].astype(np.int32)
                        for c in blocks.clusters]
        self.n_iters = plan.n_iters
        self.seed = plan.seed
        self._consumed = 0
        self._last_rng_state = None
        self._resume_rng_state = None
        return self

    @staticmethod
    def _loss_impl(params, batch, consts, cfg: GNNConfig):
        idx, w, w_self, feats, labels, valid = batch
        logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self)
        return G.gnn_loss(logits, labels, cfg.loss, cfg.n_classes,
                          valid=valid)

    def loss(self, params, batch):
        return type(self)._loss_impl(params, batch, self.loss_consts(),
                                     self.cfg)

    def _assemble(self, chosen):
        """Block-diagonal union of the chosen clusters, padded to the
        fixed (m_max, K) compile shape."""
        fd = self.graph.feats.shape[1]
        idx = np.zeros((self.m_max, self.K), np.int32)
        w = np.zeros((self.m_max, self.K), np.float32)
        w_self = np.zeros(self.m_max, np.float32)
        feats = np.zeros((self.m_max, fd), self.graph.feats.dtype)
        labels = np.zeros(self.m_max, np.int32)
        valid = np.zeros(self.m_max, np.float32)
        off = 0
        for ci in chosen:
            bi, bw = self.blocks.idx[ci], self.blocks.w[ci]
            mc, kc = bi.shape
            # local ids -> batch-local ids; padded entries (weight 0)
            # offset too, staying in-range for the gather
            idx[off:off + mc, :kc] = bi + off
            w[off:off + mc, :kc] = bw
            w_self[off:off + mc] = self.blocks.w_self[ci]
            feats[off:off + mc] = self._feats[ci]
            labels[off:off + mc] = self._labels[ci]
            valid[off:off + mc] = self._train_valid[ci]
            off += mc
        return (idx, w, w_self, feats, labels, valid), int(valid.sum())

    def _sample_union(self, rng, graph, batch_size, fanouts):
        """One assembled host batch (Prefetcher ``sample_fn`` signature:
        assembly runs on the worker thread, off the step's critical
        path, from the single ordered rng stream)."""
        train_cluster = int(np.nonzero(self._has_train)[0][0])
        for _ in range(64):          # a batch needs >= 1 training row
            chosen = rng.choice(self.n_parts_, size=self.k,
                                replace=False)
            if self._has_train[chosen].any():
                break
        else:                        # pathological split: force one in
            chosen[0] = train_cluster
        return self._assemble(chosen)

    def state_dict(self):
        return {"consumed": self._consumed,
                "rng_state": self._last_rng_state}

    def load_state_dict(self, sd):
        if not sd:
            return
        self._consumed = int(sd["consumed"])
        self._resume_rng_state = sd.get("rng_state")
        if self._consumed and self._resume_rng_state is None:
            raise ValueError(
                "ClusterSource: checkpoint records "
                f"{self._consumed} consumed batches but no rng state — "
                "cannot resume the stream exactly")

    def batches(self):
        remaining = self.n_iters - self._consumed
        self._pf = Prefetcher(self.graph, self.k, (), seed=self.seed,
                              depth=2, n_batches=remaining,
                              payload_fn=lambda g, batch: None,
                              sample_fn=self._sample_union,
                              rng_state=self._resume_rng_state)
        try:
            for _ in range(remaining):
                (host, n_valid), _ = self._pf.next()
                self._last_rng_state = self._pf.last_rng_state
                self._consumed += 1
                yield jax.device_put(host), n_valid
        finally:
            self.close()

    def close(self) -> None:
        # idempotent: Trainer's finally and the batches() finally both
        # land here
        pf, self._pf = getattr(self, "_pf", None), None
        if pf is not None:
            pf.close()


# ---------------------------------------------------------------------------
# Callbacks
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainState:
    """Mutable loop state handed to every callback hook."""
    graph: Graph
    cfg: GNNConfig
    plan: TrainPlan
    source: BatchSource
    history: History
    it: int = -1                      # current iteration (0-based)
    params: Any = None
    opt_state: Any = None
    loss: float = float("nan")        # this iteration's training loss
    val_acc: Optional[float] = None   # this iteration's eval (None = none)
    full_loss: Optional[float] = None  # precomputed tracked full loss
    n_nodes: int = 0                  # target nodes in this batch
    full_loss_fn: Optional[TCallable] = None   # params -> full objective
    stop: bool = False
    stop_reason: Optional[str] = None
    step_bad: bool = False            # this step tripped the NaN guard
    rollback_pending: bool = False    # BadStepPolicy requested a restore

    def request_stop(self, reason: str) -> None:
        if not self.stop:
            self.stop, self.stop_reason = True, reason


class Callback:
    """Hooks fire in list order; ``on_eval`` only on eval iterations,
    ``on_stop`` once when any callback requested a stop.

    Reading ``state.params`` inside a hook is always safe; a hook that
    RETAINS the arrays past its return must copy them first
    (``jax.tree.map(jnp.copy, state.params)``) — with the default
    ``plan.donate`` the next step donates those buffers (see
    docs/training_api.md "Throughput knobs")."""

    def on_train_start(self, state: TrainState) -> None: ...

    def on_step(self, state: TrainState) -> None: ...

    def on_eval(self, state: TrainState) -> None: ...

    def on_stop(self, state: TrainState) -> None: ...

    def on_train_end(self, state: TrainState) -> None: ...


class HistoryCallback(Callback):
    """Absorbs the loops' metric recording: per-iteration History rows
    plus full-objective tracking (every iteration for full-graph GD,
    every ``track_full_loss_every`` iterations for mini-batch; the
    Trainer pre-dispatches the tracked value on those iterations so the
    deferred-sync pipeline stays unbroken — ``state.full_loss``)."""

    def on_train_start(self, state):
        state.history.start()

    def on_step(self, state):
        state.history.record(state.loss, state.val_acc,
                             nodes=state.n_nodes)
        if state.step_bad:
            state.history.bad_steps.append(state.it + 1)
        if state.source.loss_is_full_loss:
            # full-graph training: the per-iteration loss IS the full loss
            state.history.full_losses.append(state.loss)
            state.history.full_loss_iters.append(state.it + 1)
        elif (state.plan.track_full_loss_every
              and state.it % state.plan.track_full_loss_every == 0):
            fl = (state.full_loss if state.full_loss is not None
                  else float(state.full_loss_fn(state.params)))
            state.history.full_losses.append(fl)
            state.history.full_loss_iters.append(state.it + 1)

    def on_train_end(self, state):
        # feature-shard / hot-cache accounting: bind-time plan stats
        # (full-graph) or the host LRU's run totals (sampled) land as
        # run-level counters next to the per-iteration series
        st = getattr(state.source, "featshard_stats", None)
        if st:
            state.history.counters.update(st)
        fc = getattr(state.source, "feat_cache", None)
        if fc is not None:
            state.history.counters.update(fc.stats())


class EarlyStop(Callback):
    """The loops' stop rules: batch loss <= target_loss (checked every
    step, AFTER recording — the crossing iteration stays in History) and
    val acc >= target_acc (checked on eval iterations)."""

    def on_step(self, state):
        tl = state.plan.target_loss
        if tl is not None and state.loss <= tl:
            state.request_stop(f"target_loss<={tl}")

    def on_eval(self, state):
        ta = state.plan.target_acc
        if ta is not None and state.val_acc is not None \
                and state.val_acc >= ta:
            state.request_stop(f"target_acc>={ta}")


def save_trainer_state(state: TrainState, final: bool = False) -> str:
    """One exact-resume snapshot: params + opt_state in the npz, the
    engine state (iteration, source stream position/rng, History) in the
    step's metadata JSON.  ``Trainer.run(resume_from=...)`` restores all
    of it and continues bit-for-bit identical to an uninterrupted run
    (test-enforced goldens)."""
    from repro.checkpoint import save_checkpoint
    meta = {
        "loss": state.loss, "it": state.it, "source": state.source.name,
        "engine_state": {
            "format": 1,
            "it": state.it,
            "seed": state.plan.seed,
            "source": state.source.name,
            "source_state": state.source.state_dict(),
            "history": state.history.to_dict(),
        },
    }
    if final:
        meta["final"] = True
    return save_checkpoint(
        state.plan.ckpt_dir, state.it,
        {"params": state.params, "opt_state": state.opt_state},
        meta, keep_last=state.plan.ckpt_keep_last or None)


class CheckpointCallback(Callback):
    """Periodic TrainerState checkpointing via ``repro.checkpoint``
    (same cadence semantics as launch/train.py's LM loop: skips step 0).
    Each save is a full exact-resume snapshot — params AND opt_state,
    source rng/stream position, History, iteration — not just params,
    so a restored run is the run the convergence curves describe."""

    def on_step(self, state):
        every = state.plan.ckpt_every
        if every and state.it and state.it % every == 0:
            save_trainer_state(state)

    def on_train_end(self, state):
        if state.plan.ckpt_every:
            save_trainer_state(state, final=True)


def default_callbacks(plan: TrainPlan) -> List[Callback]:
    cbs: List[Callback] = [HistoryCallback(), EarlyStop()]
    if plan.ckpt_every:
        cbs.append(CheckpointCallback())
    return cbs


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainResult:
    params: list
    history: History
    final_test_acc: float
    stop_reason: Optional[str] = None


class Trainer:
    """The single training engine both paradigms run through.

    Per iteration: jitted step (value_and_grad over the source's loss +
    optimizer update, params/opt_state/batch donated) -> periodic
    full-neighborhood eval -> ``on_step`` callbacks (History /
    early-stop / checkpoint) -> ``on_eval`` on eval iterations -> break
    when any callback requested a stop.  With ``plan.deferred_sync``
    the host-side readback of a record lags one iteration so the next
    step dispatches while the previous one is still in flight.
    """

    def __init__(self, graph: Graph, cfg: GNNConfig, plan: TrainPlan,
                 source: Optional[BatchSource] = None,
                 callbacks: Optional[Sequence[Callback]] = None,
                 extra_callbacks: Sequence[Callback] = ()):
        self.graph, self.cfg, self.plan = graph, cfg, plan
        self.source = (source or SampledSource()).bind(graph, cfg, plan)
        self.callbacks = (list(callbacks) if callbacks is not None
                          else default_callbacks(plan))
        self.callbacks += list(extra_callbacks)
        if plan.bad_steps.needs_ckpt() and not plan.ckpt_every:
            raise ValueError(
                "BadStepPolicy escalates to rollback but plan.ckpt_every "
                "is 0 — there would never be a checkpoint to roll back "
                "to; set ckpt_every (and ckpt_dir) or use "
                "on_bad='skip'/'raise'")
        self._consec_bad = 0             # consecutive guard-tripped steps
        self._n_rollbacks = 0
        self.opt = plan.make_optimizer()
        self._scfg = _static_cfg(cfg)
        # evaluation + full-loss tracking reuse the source's ELL when it
        # has one (FullGraphSource with max_deg: eval on the SAME capped
        # adjacency the old loop used, and no second full-width upload)
        self._ell = getattr(self.source, "ell", None) or _device_ell(graph)
        # sharded sources + kernel: eval/full-loss partition the Pallas
        # aggregation over the source's mesh too (the kernel cannot be
        # GSPMD-partitioned; einsum-path runs keep mesh=None so their
        # module-level jit cache entries stay shared with plain sources)
        self._agg_mesh = (getattr(self.source, "_mesh", None)
                          if cfg.use_agg_kernel else None)
        # featshard sources: eval/full-loss reuse the bind-time plan so
        # they run on the same NODES-sharded table as the step
        self._feats_plan = getattr(self.source, "feats_plan", None)

        if type(self.source)._loss_impl is not None:
            # built-in sources: one compiled step per (source type,
            # normalized cfg, optimizer spec, consts) PER GRAPH — shared
            # across every Trainer a sweep creates
            self._step = _cached_step(graph, type(self.source),
                                      self.source.loss_consts(), cfg,
                                      plan)
        else:
            # custom source: per-Trainer jit over the instance loss
            src, opt = self.source, self.opt

            def step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: src.loss(p, batch))(params)
                params, opt_state, good = _guarded_update(
                    opt, params, opt_state, loss, grads)
                return params, opt_state, loss, good

            self._step = jax.jit(
                step, donate_argnums=(0, 1) if plan.donate else ())

    # ------------------------------------------------------------------
    def _eval_dev(self, params, nodes):
        idx, w, w_self, feats, labels = self._ell
        return _eval_acc(params, self._scfg, idx, w, w_self, feats,
                         labels, nodes, self._agg_mesh, self._feats_plan)

    def _full_loss_dev(self, params):
        return _cached_full_loss(self.graph, self.cfg, self._ell,
                                 self.source.node_split("train"),
                                 mesh=self._agg_mesh,
                                 feats_plan=self._feats_plan)(params)

    def evaluate(self, params, nodes) -> float:
        return float(self._eval_dev(params, jnp.asarray(nodes)))

    def full_train_loss(self, params) -> float:
        return float(self._full_loss_dev(params))

    def close(self) -> None:
        """Release device references held by this Trainer (the per-graph
        ELL/step caches keep at most one resident entry; sweeps call
        this between grid points)."""
        self._ell = None
        self.source.close()

    def _fire(self, hook: str, state: TrainState) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(state)

    # ------------------------------------------------------------------
    def _consume(self, rec, state: TrainState) -> None:
        """Read one step record back to host and fire its callbacks."""
        it, loss, val, fl, n_nodes, batch, good = rec
        state.it = it
        state.loss = float(loss)           # host sync: step finished
        state.step_bad = not bool(good)
        if state.step_bad:
            self._consec_bad += 1
        else:
            self._consec_bad = 0
        state.val_acc = float(val) if val is not None else None
        state.full_loss = float(fl) if fl is not None else None
        state.n_nodes = n_nodes
        self.source.done(batch)            # staging slot recyclable
        self._fire("on_step", state)
        if state.val_acc is not None:
            self._fire("on_eval", state)
        if state.step_bad:
            self._apply_bad_step_policy(state)

    def _apply_bad_step_policy(self, state: TrainState) -> None:
        """A guard-tripped step reached the host: decide what to do.

        The in-jaxpr guard already made the bad step an identity update,
        so under ``skip`` there is nothing to undo — the next step (which
        under ``deferred_sync`` has ALREADY dispatched from the kept
        params) simply resamples.  ``rollback`` restores the latest
        checkpoint once ``max_consecutive`` bad steps pile up."""
        pol = self.plan.bad_steps
        if pol.on_bad == "raise":
            raise NonFiniteStepError(state.it, state.loss,
                                     self._consec_bad)
        if self._consec_bad < pol.max_consecutive:
            return                         # plain skip-and-resample
        escalation = (pol.escalate if pol.on_bad == "skip"
                      else "rollback")
        if escalation == "rollback":
            state.rollback_pending = True
            return
        raise NonFiniteStepError(state.it, state.loss, self._consec_bad)

    def _rollback(self, state: TrainState):
        """Restore params/opt_state from the latest checkpoint after
        ``max_consecutive`` bad steps (bounded by ``max_rollbacks``)."""
        from repro.checkpoint import latest_step, restore_checkpoint
        pol = self.plan.bad_steps
        self._n_rollbacks += 1
        if self._n_rollbacks > pol.max_rollbacks:
            raise NonFiniteStepError(state.it, state.loss,
                                     self._consec_bad)
        step = latest_step(self.plan.ckpt_dir)
        if step is None:
            # bad steps piled up before the first checkpoint cadence —
            # there is nothing to restore, surface the divergence
            raise NonFiniteStepError(state.it, state.loss,
                                     self._consec_bad)
        warnings.warn(
            f"rolling back to checkpoint step {step} after "
            f"{self._consec_bad} consecutive non-finite steps "
            f"(rollback {self._n_rollbacks}/{pol.max_rollbacks})",
            RuntimeWarning, stacklevel=2)
        tree = restore_checkpoint(
            self.plan.ckpt_dir,
            {"params": state.params, "opt_state": state.opt_state},
            step=step)
        self._consec_bad = 0
        return (self.source.place(tree["params"]),
                self.source.place(tree["opt_state"]))

    def _restore_run_state(self, directory: str, params_like,
                           opt_like):
        """Load the latest TrainerState checkpoint for exact resume."""
        from repro.checkpoint import (latest_step, load_metadata,
                                      restore_checkpoint)
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"resume_from={directory!r}: no completed checkpoints")
        meta = load_metadata(directory, step) or {}
        es = meta.get("engine_state")
        if not es:
            raise ValueError(
                f"checkpoint step {step} in {directory!r} has no "
                f"engine_state — it was not written by the engine's "
                f"CheckpointCallback (params-only checkpoints cannot "
                f"be resumed exactly)")
        if es.get("seed") != self.plan.seed:
            warnings.warn(
                f"resuming a run recorded with seed={es.get('seed')} "
                f"under plan.seed={self.plan.seed}; the continued "
                f"batch stream follows the CHECKPOINT's stream state, "
                f"not the new seed", RuntimeWarning, stacklevel=2)
        tree = restore_checkpoint(
            directory, {"params": params_like, "opt_state": opt_like},
            step=step)
        self.source.load_state_dict(es.get("source_state", {}))
        history = History.from_dict(es.get("history", {}))
        return (self.source.place(tree["params"]),
                self.source.place(tree["opt_state"]),
                int(es["it"]) + 1, history)

    def run(self, resume_from: Optional[str] = None) -> TrainResult:
        graph, cfg, plan = self.graph, self.cfg, self.plan
        key = jax.random.key(plan.seed)
        params = self.source.place(G.init_gnn(key, cfg,
                                              graph.feats.shape[1]))
        opt_state = self.source.place(self.opt.init(params))
        history, start_it = History(), 0
        if resume_from is not None:
            params, opt_state, start_it, history = \
                self._restore_run_state(resume_from, params, opt_state)

        state = TrainState(graph=graph, cfg=cfg, plan=plan,
                           source=self.source, history=history,
                           params=params, opt_state=opt_state,
                           it=start_it - 1,     # last completed iteration
                           full_loss_fn=self._full_loss_dev)
        if history.losses:
            state.loss = history.losses[-1]
        self._fire("on_train_start", state)
        deferred = _deferred_mode(plan)
        track = plan.track_full_loss_every
        track_full = track and not self.source.loss_is_full_loss
        pending = None
        try:
            val_sel = self.source.node_split("val")
            stream = self.source.batches()
            for it in range(start_it, plan.n_iters):
                batch, n_nodes = next(stream)
                # tracing happens on the first call; the donated batch
                # pytree has no batch-shaped output to alias into, so
                # XLA reports it "not usable" — expected, suppressed
                # ONLY around the tracing call so real params/opt_state
                # donation misses stay visible
                with contextlib.ExitStack() as stack:
                    if it == start_it:
                        stack.enter_context(warnings.catch_warnings())
                        warnings.filterwarnings(
                            "ignore",
                            message="Some donated buffers were not usable")
                    params, opt_state, loss, good = self._step(
                        params, opt_state, batch)
                # eval / tracked full loss are DISPATCHED here (device
                # scalars); the floats are read in _consume
                val = (self._eval_dev(params, val_sel)
                       if it % plan.eval_every == 0 else None)
                fl = (self._full_loss_dev(params)
                      if track_full and it % track == 0 else None)
                rec = (it, loss, val, fl, n_nodes, batch, good)
                state.params, state.opt_state = params, opt_state
                if deferred:
                    # lagged sync: read record i-1 while step i flies
                    prev, pending = pending, rec
                    if prev is not None:
                        self._consume(prev, state)
                else:
                    self._consume(rec, state)
                if state.rollback_pending:
                    # rollback policies require ckpt_every>0, which
                    # forces sync mode — params here are the guard-kept
                    # (pre-divergence) values being replaced
                    params, opt_state = self._rollback(state)
                    state.params, state.opt_state = params, opt_state
                    state.rollback_pending = False
                if state.stop:
                    break
            if pending is not None:
                # drain the lagged record so History stays aligned with
                # the params actually returned
                self._consume(pending, state)
            if state.stop:
                self._fire("on_stop", state)
            acc = self.evaluate(params, self.source.node_split("test"))
            state.params = params
            self._fire("on_train_end", state)
        finally:
            self.source.close()
        return TrainResult(params, state.history, acc, state.stop_reason)
