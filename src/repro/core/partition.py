"""METIS-free graph partitioning for Cluster-GCN style batching.

Cluster/subgraph batching (Chiang et al., Cluster-GCN; NVIDIA 2025
"Structure-Aware Randomized Mini-Batching") is the other mini-batch
family next to node-wise fan-out sampling: partition the graph once,
then every batch is the induced subgraph of a union of k clusters.  The
paper's (b, β) plane gets a third axis — *which* mini-batch family —
and this module provides the partitioning half of it without a METIS
dependency:

- ``bfs_partition`` — greedy BFS growing: pick an unassigned root,
  flood-fill until the part reaches its target size, repeat.  O(n + m),
  deterministic for a fixed seed, runs once per bind and is cached by
  ``ClusterSource``.
- ``cluster_ell_blocks`` — per-cluster ELL blocks over the INDUCED
  subgraph (cluster-local neighbor ids, induced-degree Ã weights).
  Because each block only contains intra-cluster edges, a batch formed
  from k clusters is exactly the block-diagonal concatenation of its
  blocks (cross-cluster edges are dropped — vanilla Cluster-GCN's
  documented approximation), so blocks are computed ONCE and batches
  assemble by offsetting local ids.

Everything here is plain numpy; the device side lives in
``engine.ClusterSource``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List

import numpy as np

from repro.core.graph import Graph, neighbors_batch


def bfs_partition(graph: Graph, n_parts: int, seed: int = 0) -> np.ndarray:
    """Partition nodes into <= ``n_parts`` contiguous-ish parts by greedy
    BFS growing.  Returns an int32 part id per node (all >= 0).

    Each part grows from a randomly-ordered root until it holds
    ``ceil(n / n_parts)`` nodes (disconnected leftovers start a new BFS
    inside the same part, so parts stay size-balanced even on fragmented
    graphs); the last part absorbs any remainder.  ``n_parts >= n``
    degenerates to single-node parts.
    """
    n = graph.n
    if n_parts < 1:
        raise ValueError(f"bfs_partition: n_parts must be >= 1, got "
                         f"{n_parts}")
    n_parts = min(n_parts, n)
    target = -(-n // n_parts)                      # ceil(n / n_parts)
    part = np.full(n, -1, np.int32)
    order = np.random.default_rng(seed).permutation(n)
    ptr = 0                                        # next root candidate
    assigned = 0
    pid = 0
    while assigned < n:
        budget = n - assigned if pid == n_parts - 1 else target
        size = 0
        q: deque = deque()
        while size < budget:
            if not q:
                while ptr < n and part[order[ptr]] >= 0:
                    ptr += 1
                if ptr == n:
                    break
                root = int(order[ptr])
                part[root] = pid
                size += 1
                assigned += 1
                q.append(root)
                continue
            u = q.popleft()
            for v in graph.neighbors(u):
                if part[v] < 0 and size < budget:
                    part[v] = pid
                    size += 1
                    assigned += 1
                    q.append(v)
        pid += 1
    return part


def partition_clusters(part: np.ndarray) -> List[np.ndarray]:
    """Part-id array -> list of sorted node-id arrays (non-empty parts
    only, in part-id order)."""
    out = []
    for p in range(int(part.max()) + 1):
        c = np.nonzero(part == p)[0].astype(np.int64)
        if c.size:
            out.append(c)
    return out


@dataclasses.dataclass
class ClusterBlocks:
    """Cached per-cluster induced-subgraph ELL blocks (host side).

    ``idx[c]`` holds CLUSTER-LOCAL neighbor ids ([m_c, K_c], int32);
    ``w[c]`` the induced-degree Ã edge weights (zero on padding);
    ``w_self[c]`` the induced self-loop weight 1 / (d_induced + 1).
    A batch of k clusters is the block-diagonal stack: offset each
    block's local ids by the running row count and pad K to the max.
    """
    clusters: List[np.ndarray]
    idx: List[np.ndarray]
    w: List[np.ndarray]
    w_self: List[np.ndarray]

    @property
    def sizes(self) -> np.ndarray:
        return np.array([len(c) for c in self.clusters], np.int64)

    @property
    def max_width(self) -> int:
        return max((b.shape[1] for b in self.idx), default=1)


def cluster_ell_blocks(graph: Graph, part: np.ndarray) -> ClusterBlocks:
    """Induced-subgraph ELL blocks for every cluster of ``part``.

    Weights follow the repo's Ã convention restricted to the induced
    subgraph: w_uv = 1/sqrt((d_u + 1)(d_v + 1)) with d the INDUCED
    degree, w_self = 1/(d_u + 1) — a single-node cluster is the fixed
    point (no edges, w_self = 1).
    """
    clusters = partition_clusters(part)
    loc = np.full(graph.n, -1, np.int64)
    idxs, ws, w_selfs = [], [], []
    for c in clusters:
        loc[c] = np.arange(c.size)
        nb, valid = neighbors_batch(graph, c)      # [m, width], global ids
        lnb = loc[nb]
        inb = valid & (lnb >= 0)                   # in-cluster edges only
        ideg = inb.sum(1).astype(np.int64)         # induced degree
        k = max(int(ideg.max()) if ideg.size else 0, 1)
        # compact in-cluster entries to the front (stable: CSR order kept)
        keep = np.argsort(~inb, axis=1, kind="stable")[:, :k]
        lidx = np.take_along_axis(np.where(inb, lnb, 0), keep, 1)
        m = np.take_along_axis(inb, keep, 1)
        dv = ideg[lidx]                            # neighbor induced degree
        w = (m / np.sqrt((ideg[:, None] + 1.0) * (dv + 1.0))
             ).astype(np.float32)
        idxs.append(lidx.astype(np.int32))
        ws.append(w)
        w_selfs.append((1.0 / (ideg + 1.0)).astype(np.float32))
        loc[c] = -1                                # reset for next cluster
    return ClusterBlocks(clusters=clusters, idx=idxs, w=ws, w_self=w_selfs)
