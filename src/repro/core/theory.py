"""The paper's one-layer theory testbed (§2-§4, App. B-E) and closed-form
iteration-complexity bounds (Theorems 1, 2, B.4, D.2) + the Remark 3.2
slope magnitudes |dT/dβ|.

Conventions follow the appendix: σ(x) = √2·max(x, 0); MSE carries the 1/2;
CE is binary with the fixed ±1 output vector v.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
SQRT2 = math.sqrt(2.0)


# ---------------------------------------------------------------------------
# one-layer GNN testbed
# ---------------------------------------------------------------------------

def init_testbed(key, feat_dim: int, hidden: int):
    """W ~ N(0, κ² I) with κ = 1 (App. B)."""
    return jax.random.normal(key, (hidden, feat_dim), F32)


def testbed_forward(w, agg_feats):
    """z_i = σ(ã_i X Wᵀ), σ = √2 relu.  agg_feats [m, r] = Ã X rows."""
    return SQRT2 * jax.nn.relu(agg_feats @ w.T)


def testbed_mse_loss(w, agg_feats, onehot):
    """l = ½‖ŷ − y‖²  (App. B: hidden dim h = K classes)."""
    z = testbed_forward(w, agg_feats)
    return 0.5 * jnp.mean(jnp.sum(jnp.square(z - onehot), axis=-1))


def testbed_ce_loss(w, agg_feats, y_pm, v):
    """Binary CE (App. D): ŷ_i = σ(ã_i X Wᵀ)vᵀ, l = log(1+exp(−y ŷ))."""
    z = testbed_forward(w, agg_feats)
    yhat = z @ v
    return jnp.mean(jnp.log1p(jnp.exp(-y_pm * yhat)))


def make_v(hidden: int) -> jnp.ndarray:
    """Fixed output vector: half +1 / half −1 (App. D)."""
    v = np.ones(hidden, np.float32)
    v[hidden // 2:] = -1.0
    return jnp.asarray(v)


# ---------------------------------------------------------------------------
# Γ, Υ-style graph quantities (App. B/C) — diagnostics
# ---------------------------------------------------------------------------

def gamma_bounds(row_sums: np.ndarray) -> Dict[str, float]:
    """Lemma B.5/C.1: ‖Ã1‖₁/(π m) ≤ Γ ≤ ‖Ã1‖₁/m."""
    m = len(row_sums)
    l1 = float(np.abs(row_sums).sum())
    return {"gamma_lower": l1 / (math.pi * m), "gamma_upper": l1 / m,
            "row_l1": l1}


# ---------------------------------------------------------------------------
# iteration-complexity bounds
# ---------------------------------------------------------------------------

def t_mse_minibatch(n_train: int, h: int, b: int, beta: float,
                    eps: float = 0.1) -> float:
    """Theorem 1:  T = O(n h² b^{5/2} β^{-1/2} ε^{-1} log(h²/ε))."""
    return (n_train * h ** 2 * b ** 2.5 * beta ** -0.5 / eps
            * math.log(h ** 2 / eps))


def t_mse_fullgraph(n_train: int, h: int, d_max: float,
                    eps: float = 0.1) -> float:
    """Theorem B.4:  T = O(n^{7/2} h² d_max^{-1/2} ε^{-1} log(h²/ε))."""
    return (n_train ** 3.5 * h ** 2 * d_max ** -0.5 / eps
            * math.log(h ** 2 / eps))


def t_ce_minibatch(n_train: int, b: int, beta: float, alpha: float = 1.0,
                   eps: float = 0.1) -> float:
    """Theorem 2:  T = O(n² √log n · α⁻² b⁻¹ β^{-5/2} (n² + ε⁻¹))."""
    return (n_train ** 2 * math.sqrt(math.log(max(n_train, 2)))
            / (alpha ** 2 * b * beta ** 2.5)
            * (n_train ** 2 + 1.0 / eps))


def t_ce_fullgraph(n_train: int, d_max: float, alpha: float = 1.0,
                   eps: float = 0.1) -> float:
    """Theorem D.2:  T = O(n √log n · α⁻² d_max^{-5/2} (n² + ε⁻¹))."""
    return (n_train * math.sqrt(math.log(max(n_train, 2)))
            / (alpha ** 2 * d_max ** 2.5) * (n_train ** 2 + 1.0 / eps))


def slope_mse(b: int, beta: float) -> float:
    """Remark 3.2: |∂T/∂β| = O(β^{-3/2} b^{5/2}) under MSE."""
    return beta ** -1.5 * b ** 2.5


def slope_ce(b: int, beta: float) -> float:
    """Remark 3.2: |∂T/∂β| = O(β^{-7/2} b^{-1}) under CE."""
    return beta ** -3.5 / b


def predicted_trends() -> Dict[str, str]:
    """Remark 3.1 qualitative predictions (validated in benchmarks)."""
    return {
        "mse_batch": "increasing b -> MORE iterations (T ~ b^{5/2})",
        "ce_batch": "increasing b -> FEWER iterations (T ~ 1/b)",
        "mse_fanout": "increasing beta -> fewer iterations (T ~ β^{-1/2})",
        "ce_fanout": "increasing beta -> fewer iterations (T ~ β^{-5/2})",
    }
