# The paper's primary contribution: full-graph vs mini-batch GNN training,
# with the (batch size b, fan-out size β) analysis machinery.
from repro.core.graph import Graph, to_ell, full_adjacency_dense  # noqa: F401
from repro.core.sampler import sample_batch, expand_batch, FanoutBatch  # noqa: F401
from repro.core.gnn import init_gnn, full_graph_forward, minibatch_forward, gnn_loss, accuracy  # noqa: F401
from repro.core.trainer import train_full_graph, train_minibatch, TrainResult  # noqa: F401
from repro.core.engine import (  # noqa: F401
    Trainer, TrainPlan, BatchSource, FullGraphSource, SampledSource,
    ClusterSource, ImportanceSampledSource, ShardedSampledSource,
    ShardedFullGraphSource, BadStepPolicy, NonFiniteStepError,
    Callback, HistoryCallback, EarlyStop, CheckpointCallback,
    save_trainer_state)
from repro.core.experiment import run_experiment, sweep, save_rows  # noqa: F401
from repro.core.inference import (  # noqa: F401
    InferenceRun, layerwise_embeddings, layerwise_layers, layerwise_logits)
from repro.core.embedding_store import EmbeddingStore, TableSnapshot  # noqa: F401
from repro.core.serving import (  # noqa: F401
    GNNServer, ServeStats, ServedAnswer, ServerOverloadedError,
    DeadlineExceededError)
from repro.core import faults, theory, metrics, wasserstein  # noqa: F401
