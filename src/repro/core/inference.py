"""Layer-wise full-graph GNN inference (the serving tier's embedding
pass; docs/training_api.md "Inference & serving").

Training-time mini-batch inference pays exponential fan-out: answering b
queries through a k-layer model touches O(b · Π β_l) nodes.  Layer-wise
inference (the inference_helper design, SNIPPETS.md Snippet 1) inverts
the loop order: materialize ALL nodes' layer-l embeddings before any
layer-(l+1) work, so a k-layer model over n nodes costs O(k · n) ELL
gathers total and every query afterwards is a table lookup.

The node axis is CHUNKED: each layer streams [chunk_size]-row slices of
the host ELL through the existing aggregation paths —
``cfg.use_agg_kernel`` routes a chunk through the batch-tiled Pallas
kernel (shard-locally over a NODES mesh when ``mesh`` is given, the PR-5
sharded path), otherwise the einsum gather.  Chunk staging reuses the
engine's ``Prefetcher`` + ``HostStagingRing``: a background thread
copies the next chunk's ELL rows into recycled staging buffers while
the device computes the current one.

Equivalence contract (test-enforced, tests/test_inference.py):
- per-layer ``allclose`` with the naive ``full_graph_forward`` for every
  model and both aggregation paths, at any chunk size (including ones
  that do not divide n);
- on a 1-device mesh the kernel path is BIT-identical to the unsharded
  kernel path (inherited from ``neighbor_agg_sharded``);
- ``prefetch`` on/off is bit-identical (same chunks, same ops).

``core.embedding_store`` builds the cached per-layer tables on top of
this; ``core.serving`` answers queries from them.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core import faults
from repro.core import gnn as G
from repro.core.engine import _static_cfg
from repro.core.graph import Graph, to_ell
from repro.core.prefetch import HostStagingRing, Prefetcher


# ---------------------------------------------------------------------------
# Compiled per-chunk layer step
# ---------------------------------------------------------------------------

@jax.jit
def _matmul(h, wmat):
    return h @ wmat


def _pre_source(cfg: GNNConfig, p, h):
    """The full forward's width-shrinking trick, once per LAYER (not per
    chunk): when a layer narrows (d_out < d_in) the linear transform
    runs before aggregation (Ã(hW) == (Ãh)W), so every chunk gathers
    d_out-wide rows.  GAT gathers raw ``h`` (per-edge attention)."""
    wmat = p.get("w") if cfg.model == "gcn" else p.get("w_neigh")
    if wmat is not None and wmat.shape[1] < h.shape[1]:
        return _matmul(h, wmat)
    return h


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _chunk_apply(cfg: GNNConfig, last: bool, mesh, p, h, src, rows, idx,
                 w, w_self):
    """One node-chunk of one layer, mirroring ``full_graph_forward``'s
    per-layer body row-sliced to the chunk.

    ``h`` [n, d_in] is the full previous-layer table, ``src`` the
    (possibly pre-transformed) gather source table; ``rows`` [c] are the
    chunk's global node ids, ``idx``/``w`` [c, K] its ELL rows and
    ``w_self`` [c] the self-loop weights.  Padded tail rows carry zero
    weights (their aggregation is exactly zero) and are trimmed by the
    caller.  Jitted once per (normalized cfg, last, mesh, shapes) at
    module level, so the store's incremental re-embeds reuse the build
    pass's compiled functions.
    """
    agg_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype
    maskb = w > 0
    mask = maskb.astype(h.dtype)
    # cast the bool mask straight to agg_dt where aggregation consumes
    # it — bool->f32->bf16 was a second full [c, K] pass under bf16
    mask_agg = mask if agg_dt == h.dtype else maskb.astype(agg_dt)

    def agg_w(table, w_edge):
        t = table.astype(agg_dt)
        if cfg.use_agg_kernel:
            return G._kernel_agg(cfg, t, idx, w_edge.astype(agg_dt),
                                 mesh=mesh).astype(h.dtype)
        return jnp.einsum("ck,ckd->cd", w_edge.astype(agg_dt),
                          jnp.take(t, idx, axis=0)).astype(h.dtype)

    if cfg.model == "gcn":
        wmat = p["w"]
        pre = wmat.shape[1] < h.shape[1]
        if cfg.use_agg_kernel:
            # fused epilogue: the chunk's self rows come from the same
            # cast source table the kernel gathers from
            srcr = src.astype(agg_dt)
            agg = G._kernel_agg(cfg, srcr, idx, w.astype(agg_dt),
                                self_rows=jnp.take(srcr, rows, axis=0),
                                w_self=w_self.astype(agg_dt),
                                mesh=mesh).astype(h.dtype)
        else:
            agg = agg_w(src, w) \
                + w_self[:, None] * jnp.take(src, rows, axis=0)
        out = agg if pre else agg @ wmat
    elif cfg.model == "graphsage":
        wn = p["w_neigh"]
        pre = wn.shape[1] < h.shape[1]
        cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
        mean = agg_w(src, mask_agg) / cnt
        out = jnp.take(h, rows, axis=0) @ p["w_self"] \
            + (mean if pre else mean @ wn)
    else:  # gat — per-edge softmax attention stays on the einsum path
        h_rows = jnp.take(h, rows, axis=0)
        nb = jnp.take(h.astype(agg_dt), idx, axis=0).astype(h.dtype)
        out = G._gat_layer(p, h_rows, nb, maskb)
        if last:
            heads = cfg.gat_heads
            out = out.reshape(out.shape[:-1] + (heads, -1)).mean(-2)
    return out if last else jax.nn.relu(out)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _featshard_layer(cfg: GNNConfig, last: bool, fsplan, p, h, w, w_self):
    """One FULL layer over the NODES-sharded table (feats_layout =
    "sharded"): no chunk loop and no replicated source anywhere — the
    whole [n_pad, d] table stays row-sharded, layer l's output feeds
    layer l+1 in place (the ISSUE's "layer tables stay NODES-sharded"
    serving requirement).  Mirrors ``full_graph_forward``'s gcn /
    graphsage bodies through ``neighbor_agg_featshard``; ``fsplan`` is
    the identity-hashed static plan for THIS ell/mesh."""
    from repro.kernels.neighbor_agg.ops import neighbor_agg_featshard
    agg_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype
    kw = dict(interpret=cfg.agg_interpret, b_tile=cfg.agg_b_tile,
              d_tile=cfg.agg_d_tile, k_slab=cfg.agg_k_slab)
    if cfg.model == "gcn":
        wmat = p["w"]
        pre = wmat.shape[1] < h.shape[1]
        srcr = ((h @ wmat) if pre else h).astype(agg_dt)
        agg = neighbor_agg_featshard(
            srcr, w.astype(agg_dt), fsplan, self_rows=srcr,
            w_self=w_self.astype(agg_dt), **kw).astype(h.dtype)
        out = agg if pre else agg @ wmat
    else:  # graphsage
        wn = p["w_neigh"]
        pre = wn.shape[1] < h.shape[1]
        src = (h @ wn) if pre else h
        maskb = w > 0
        mask = maskb.astype(h.dtype)
        cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
        # bool -> agg_dt directly (not via the f32 mask): one cast pass
        mask_agg = mask if agg_dt == h.dtype else maskb.astype(agg_dt)
        mean = neighbor_agg_featshard(
            src.astype(agg_dt), mask_agg, fsplan,
            **kw).astype(h.dtype) / cnt
        out = h @ p["w_self"] + (mean if pre else mean @ wn)
    return out if last else jax.nn.relu(out)


# ---------------------------------------------------------------------------
# Chunk staging pipeline (Prefetcher + HostStagingRing reuse)
# ---------------------------------------------------------------------------

class _ChunkStream:
    """Sequential [chunk_size]-row slices of the host ELL, staged into
    recycled ``HostStagingRing`` buffers — by a background ``Prefetcher``
    thread by default, so host-side slicing/padding overlaps the device
    compute of the previous chunk.  The chunk sequence CYCLES: one full
    pass per layer (``passes`` = n_layers), since the ELL rows are
    layer-independent."""

    def __init__(self, ell: Tuple[np.ndarray, np.ndarray, np.ndarray],
                 n: int, chunk_size: int, passes: int,
                 prefetch: bool = True, depth: int = 2):
        self._idx, self._w, self._w_self = ell
        self.n = n
        self.cs = chunk_size
        self.K = self._idx.shape[1]
        self.n_chunks = -(-n // chunk_size)
        # queued payloads (depth) + one being staged + one at the consumer
        self._ring = HostStagingRing(depth + 2)
        counter = itertools.count()

        def sample_fn(rng, graph, batch_size, fanouts):
            return next(counter) % self.n_chunks

        self._sample = sample_fn
        self._pf: Optional[Prefetcher] = None
        if prefetch:
            self._pf = Prefetcher(
                None, 0, (), seed=0, depth=depth,
                n_batches=passes * self.n_chunks,
                payload_fn=self._stage, sample_fn=sample_fn)

    def _stage(self, graph, ci: int):
        """Copy chunk ``ci``'s ELL rows into a staging slot (padded to
        the fixed chunk width with zero-weight rows, so every chunk has
        ONE compiled shape).  Runs on the Prefetcher worker thread."""
        c0 = ci * self.cs
        c1 = min(c0 + self.cs, self.n)
        m = c1 - c0
        specs = [((self.cs,), np.int32), ((self.cs, self.K), np.int32),
                 ((self.cs, self.K), np.float32), ((self.cs,), np.float32)]
        slot = self._ring.acquire()
        try:
            rows_b, idx_b, w_b, ws_b = self._ring.buffers(slot, specs)
            rows_b[:m] = np.arange(c0, c1, dtype=np.int32)
            idx_b[:m] = self._idx[c0:c1]
            w_b[:m] = self._w[c0:c1]
            ws_b[:m] = self._w_self[c0:c1]
            if m < self.cs:          # zero-weight padding rows
                rows_b[m:] = 0
                idx_b[m:] = 0
                w_b[m:] = 0.0
                ws_b[m:] = 0.0
        except BaseException:
            # never strand a slot on a dying worker (engine convention)
            self._ring.release(slot)
            raise
        return slot, (rows_b, idx_b, w_b, ws_b, m)

    def next(self):
        """-> ((rows, idx, w, w_self) device arrays, n_valid, slot).

        CPU ``device_put`` ZERO-COPIES sufficiently aligned host buffers
        — the returned device arrays may alias the slot's staging
        memory, so the slot must stay unreleased until the chunk's
        consuming COMPUTATION has finished (the engine's release-after-
        step-sync rule), not merely until the transfer lands.  The
        caller hands the slot back via ``release`` after syncing."""
        if self._pf is not None:
            _, payload = self._pf.next()
        else:
            payload = self._stage(None, self._sample(None, None, 0, ()))
        slot, (rows, idxb, wb, wsb, m) = payload
        dev = jax.device_put((rows, idxb, wb, wsb))
        return dev, m, slot

    def release(self, slot: int) -> None:
        self._ring.release(slot)

    def close(self):
        self._ring.close()
        if self._pf is not None:
            pf, self._pf = self._pf, None
            pf.close()


# ---------------------------------------------------------------------------
# Layer-wise inference
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class InferenceRun:
    """Per-layer embedding tables plus timing stats.

    ``layers[l]`` is the POST-activation [n, d_l] table (what feeds
    layer l+1); ``layers[-1]`` are the logits — per-layer equal to
    ``full_graph_forward(..., return_layers=True)``."""
    layers: List[jax.Array]
    stats: Dict[str, float]

    @property
    def logits(self):
        return self.layers[-1]


def _featshard_run(params, scfg: GNNConfig, feats, ell,
                   fsplan) -> InferenceRun:
    """The featshard inference pass: per-layer tables NODES-sharded over
    ``fsplan.mesh`` end-to-end.  No chunk stream — the plan already
    splits every row's gather into shard-local hits and one compacted
    cold all_gather, so each layer is ONE sharded device step and the
    per-device high-water mark is O(n·d / S + C·d), never a full
    table."""
    from repro import sharding as sh
    if scfg.model not in ("gcn", "graphsage") or not scfg.use_agg_kernel:
        raise ValueError(
            "featshard inference needs use_agg_kernel=True and a "
            f"gcn/graphsage model, got model={scfg.model!r}, "
            f"use_agg_kernel={scfg.use_agg_kernel} (GAT's attention "
            "gather is not a weighted sum — use the chunked path)")
    idx, w, w_self = ell
    n = int(feats.shape[0])
    pad = fsplan.n_pad - n
    if pad < 0 or w.shape != (n, fsplan.K):
        raise ValueError(
            f"featshard inference: ELL shape {w.shape} does not match "
            f"the plan (n_pad={fsplan.n_pad}, K={fsplan.K}) — build the "
            f"plan from THIS ell/mesh (layerwise_embeddings does)")
    feats = np.asarray(feats)
    if pad:                      # zero rows/weights: aggregate to zero
        feats = np.pad(feats, ((0, pad), (0, 0)))
        w = np.pad(w, ((0, pad), (0, 0)))
        w_self = np.pad(w_self, (0, pad))
    mesh = fsplan.mesh
    rows2 = sh.named((sh.NODES, None), mesh)
    row1 = sh.named((sh.NODES,), mesh)
    h = jax.device_put(np.ascontiguousarray(feats), rows2)
    w_d = jax.device_put(np.ascontiguousarray(w), rows2)
    ws_d = jax.device_put(np.ascontiguousarray(w_self), row1)
    layers: List[jax.Array] = []
    per_layer: List[float] = []
    t0 = time.perf_counter()
    for li, p in enumerate(params):
        lt0 = time.perf_counter()
        last = li == len(params) - 1
        h = _featshard_layer(scfg, last, fsplan, p, h, w_d, ws_d)
        jax.block_until_ready(h)
        # h itself stays padded + NODES-sharded for the next layer; the
        # returned table is trimmed to the real rows
        layers.append(h[:n] if pad else h)
        per_layer.append(round(time.perf_counter() - lt0, 6))
        faults.maybe_crash("infer.after_layer")
    total = time.perf_counter() - t0
    d = feats.shape[1]
    item = 2 if scfg.dtype == "bfloat16" else np.dtype(feats.dtype).itemsize
    stats = {
        "n_nodes": n, "n_layers": len(params), "chunk_size": n,
        "n_chunks": 1, "chunk_steps": len(params),
        "total_s": round(total, 6), "per_layer_s": per_layer,
        "ms_per_node": round(1000.0 * total / n, 6),
        "feat_table_bytes_per_device": fsplan.table_bytes_per_device(
            d, item),
        "feat_remote_gather_bytes": fsplan.remote_bytes_per_call(d, item),
        **fsplan.stats,
    }
    return InferenceRun(layers=layers, stats=stats)


def layerwise_layers(params, cfg: GNNConfig, feats,
                     ell: Tuple[np.ndarray, np.ndarray, np.ndarray], *,
                     chunk_size: int = 1024, mesh=None,
                     prefetch: bool = True, feats_plan=None
                     ) -> InferenceRun:
    """Layer-wise inference over host ELL arrays ``(idx, w, w_self)``.

    Per layer: the (optional) width-shrinking pre-transform runs ONCE on
    the full table, then every node chunk aggregates against it through
    the configured kernel/einsum path; the concatenated rows become the
    next layer's table.  Memory high-water mark is O(n · d) tables plus
    one [chunk, K, d] gather — never the [n, K, d] blowup, and never the
    exponential fan-out tree.

    ``feats_plan`` (a ``FeatShardPlan`` built from THIS ell) switches to
    the NODES-sharded table pass (``_featshard_run``): chunking and
    ``mesh`` are ignored — the plan's mesh partitions everything and
    every per-layer table stays row-sharded."""
    scfg = _static_cfg(cfg)
    if feats_plan is not None:
        return _featshard_run(params, scfg, feats, ell, feats_plan)
    n = int(feats.shape[0])
    if n == 0:
        raise ValueError("layerwise_layers: empty graph (n=0)")
    cs = max(1, min(int(chunk_size) if chunk_size else n, n))
    h = jnp.asarray(feats)
    stream = _ChunkStream(ell, n, cs, passes=len(params),
                          prefetch=prefetch)
    layers: List[jax.Array] = []
    per_layer: List[float] = []
    t0 = time.perf_counter()
    try:
        for li, p in enumerate(params):
            lt0 = time.perf_counter()
            last = li == len(params) - 1
            src = _pre_source(scfg, p, h)
            outs = []
            for _ in range(stream.n_chunks):
                (rows, cidx, cw, cws), m, slot = stream.next()
                out = _chunk_apply(scfg, last, mesh, p, h, src, rows,
                                   cidx, cw, cws)
                # sync BEFORE recycling the slot: the chunk operands may
                # alias the staging buffers (zero-copy device_put)
                jax.block_until_ready(out)
                stream.release(slot)
                outs.append(out if m == cs else out[:m])
            h = outs[0] if len(outs) == 1 else jnp.concatenate(outs, 0)
            jax.block_until_ready(h)
            layers.append(h)
            per_layer.append(round(time.perf_counter() - lt0, 6))
            faults.maybe_crash("infer.after_layer")
    finally:
        stream.close()
    total = time.perf_counter() - t0
    stats = {
        "n_nodes": n, "n_layers": len(params), "chunk_size": cs,
        "n_chunks": stream.n_chunks,
        "chunk_steps": len(params) * stream.n_chunks,
        "total_s": round(total, 6),
        "per_layer_s": per_layer,
        "ms_per_node": round(1000.0 * total / n, 6),
    }
    return InferenceRun(layers=layers, stats=stats)


def layerwise_embeddings(params, cfg: GNNConfig, graph: Graph, *,
                         max_deg: Optional[int] = None,
                         chunk_size: int = 1024, mesh=None,
                         prefetch: bool = True,
                         feats_plan=None) -> InferenceRun:
    """Layer-wise inference straight from a ``Graph`` (ELL derived here;
    ``max_deg=None`` keeps ALL neighbors — inference uses the full
    neighborhood, §4.1).  Under ``cfg.feats_layout == "sharded"`` with
    the kernel on and a ``mesh``, a featshard plan is built from this
    inference ELL (NOT reused from training — the full neighborhood has
    its own K) and the NODES-sharded table pass runs instead of the
    chunk stream."""
    ell = to_ell(graph, max_deg=max_deg)
    if (feats_plan is None and cfg.feats_layout == "sharded"
            and cfg.use_agg_kernel and mesh is not None
            and cfg.model in ("gcn", "graphsage")):
        from repro import sharding as sh
        from repro.kernels.neighbor_agg.ops import build_featshard_plan
        idx, w, _ = ell
        pad = (-graph.n) % sh.nodes_shards(mesh)
        if pad:
            idx = np.pad(idx, ((0, pad), (0, 0)))
            w = np.pad(w, ((0, pad), (0, 0)))
        feats_plan = build_featshard_plan(
            idx, w, graph.degrees, mesh,
            cache_rows=cfg.feat_cache_rows)
    return layerwise_layers(params, cfg, graph.feats, ell,
                            chunk_size=chunk_size, mesh=mesh,
                            prefetch=prefetch, feats_plan=feats_plan)


def layerwise_logits(params, cfg: GNNConfig, graph: Graph,
                     **kw) -> jax.Array:
    """Final-layer logits [n, C] only."""
    return layerwise_embeddings(params, cfg, graph, **kw).logits
