"""Graph containers and normalized adjacency (paper §2).

Ã = (D_in + I)^{-1/2} (A + I) (D_out + I)^{-1/2}   (self-loops included)

Two padded device layouts:
  * ELL  — [n, max_deg] neighbor ids + ã weights, for full-graph training
           (TPU-friendly fixed-width rows; the paper's irregular graphs are
           handled by masking).
  * fan-out trees — per-hop [b, f1, ..., fd] id/weight tensors produced by
    the sampler for mini-batch training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """CSR undirected graph with features/labels/splits (host side)."""
    n: int
    indptr: np.ndarray          # [n+1]
    indices: np.ndarray         # [nnz]
    feats: np.ndarray           # [n, r] float32
    labels: np.ndarray          # [n] int32
    train_mask: np.ndarray      # [n] bool
    val_mask: np.ndarray
    test_mask: np.ndarray

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def d_max(self) -> int:
        return int(self.degrees.max())

    @property
    def avg_degree(self) -> float:
        return float(self.degrees.mean())

    @property
    def n_classes(self) -> int:
        return int(self.labels.max()) + 1

    @property
    def train_nodes(self) -> np.ndarray:
        return np.nonzero(self.train_mask)[0].astype(np.int32)

    @property
    def test_nodes(self) -> np.ndarray:
        return np.nonzero(self.test_mask)[0].astype(np.int32)

    @property
    def val_nodes(self) -> np.ndarray:
        return np.nonzero(self.val_mask)[0].astype(np.int32)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


def norm_coef(graph: Graph, rows: np.ndarray, cols: np.ndarray,
              row_deg: Optional[np.ndarray] = None) -> np.ndarray:
    """ã weights for edges (rows -> cols): 1/sqrt((din_r+1)(dout_c+1)).
    `row_deg` overrides the row in-degree (mini-batch: # sampled = β)."""
    deg = graph.degrees
    din = deg[rows] if row_deg is None else row_deg
    dout = deg[cols]
    return (1.0 / np.sqrt((din + 1.0) * (dout + 1.0))).astype(np.float32)


def to_ell(graph: Graph, max_deg: Optional[int] = None, rows=None
           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Padded neighbor lists with ã weights (+ the self-loop weight).

    Returns (idx [m, K], w [m, K], w_self [m]) where m = len(rows) (default
    all nodes).  Rows with degree > K keep the K highest-weight neighbors
    (documented truncation; max_deg defaults to d_max = no truncation).
    """
    rows = np.arange(graph.n, dtype=np.int32) if rows is None else rows
    k = max_deg or graph.d_max
    m = len(rows)
    idx = np.zeros((m, k), np.int32)
    w = np.zeros((m, k), np.float32)
    deg = graph.degrees
    for out_i, u in enumerate(rows):
        nb = graph.neighbors(u)
        cw = norm_coef(graph, np.full(len(nb), u), nb)
        if len(nb) > k:
            keep = np.argsort(-cw)[:k]
            nb, cw = nb[keep], cw[keep]
        idx[out_i, :len(nb)] = nb
        w[out_i, :len(nb)] = cw
    w_self = (1.0 / (deg[rows] + 1.0)).astype(np.float32)
    return idx, w, w_self


def full_adjacency_dense(graph: Graph) -> np.ndarray:
    """Dense Ã (n x n) with self-loops — only for small theory/test graphs
    and the Wasserstein analysis."""
    a = np.zeros((graph.n, graph.n), np.float32)
    for u in range(graph.n):
        nb = graph.neighbors(u)
        a[u, nb] = 1.0
    a[np.arange(graph.n), np.arange(graph.n)] = 1.0
    deg = graph.degrees + 1.0
    dm = 1.0 / np.sqrt(deg)
    return (a * dm[:, None]) * dm[None, :]
