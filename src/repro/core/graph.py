"""Graph containers and normalized adjacency (paper §2).

Ã = (D_in + I)^{-1/2} (A + I) (D_out + I)^{-1/2}   (self-loops included)

Two padded device layouts:
  * ELL  — [n, max_deg] neighbor ids + ã weights, for full-graph training
           (TPU-friendly fixed-width rows; the paper's irregular graphs are
           handled by masking).
  * fan-out trees — per-hop [b, f1, ..., fd] id/weight tensors produced by
    the sampler for mini-batch training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Graph:
    """CSR undirected graph with features/labels/splits (host side)."""
    n: int
    indptr: np.ndarray          # [n+1]
    indices: np.ndarray         # [nnz]
    feats: np.ndarray           # [n, r] float32
    labels: np.ndarray          # [n] int32
    train_mask: np.ndarray      # [n] bool
    val_mask: np.ndarray
    test_mask: np.ndarray

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def d_max(self) -> int:
        return int(self.degrees.max())

    @property
    def avg_degree(self) -> float:
        return float(self.degrees.mean())

    @property
    def n_classes(self) -> int:
        return int(self.labels.max()) + 1

    @property
    def train_nodes(self) -> np.ndarray:
        return np.nonzero(self.train_mask)[0].astype(np.int32)

    @property
    def test_nodes(self) -> np.ndarray:
        return np.nonzero(self.test_mask)[0].astype(np.int32)

    @property
    def val_nodes(self) -> np.ndarray:
        return np.nonzero(self.val_mask)[0].astype(np.int32)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]


def norm_coef(graph: Graph, rows: np.ndarray, cols: np.ndarray,
              row_deg: Optional[np.ndarray] = None) -> np.ndarray:
    """ã weights for edges (rows -> cols): 1/sqrt((din_r+1)(dout_c+1)).
    `row_deg` overrides the row in-degree (mini-batch: # sampled = β)."""
    deg = graph.degrees
    din = deg[rows] if row_deg is None else row_deg
    dout = deg[cols]
    return (1.0 / np.sqrt((din + 1.0) * (dout + 1.0))).astype(np.float32)


def neighbors_batch(graph: Graph, rows: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized ragged CSR gather: padded [m, d_max(rows)] neighbor ids
    plus a validity mask, with NO per-node Python loop.  Column j of row i
    is the j-th CSR neighbor of rows[i] (CSR order preserved)."""
    rows = np.asarray(rows, np.int64)
    start = graph.indptr[rows]
    deg = (graph.indptr[rows + 1] - start).astype(np.int64)
    width = int(deg.max()) if deg.size else 0
    cols = np.arange(max(width, 1), dtype=np.int64)[None, :]
    valid = cols < deg[:, None]
    if graph.indices.size == 0:              # edgeless graph
        return np.zeros(valid.shape, np.int32), valid
    # clamp padded positions to 0 — masked out below, never read OOB
    pos = np.where(valid, start[:, None] + cols, 0)
    nb = graph.indices[pos].astype(np.int32)
    nb[~valid] = 0
    return nb, valid


def to_ell(graph: Graph, max_deg: Optional[int] = None, rows=None
           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Padded neighbor lists with ã weights (+ the self-loop weight).

    Returns (idx [m, K], w [m, K], w_self [m]) where m = len(rows) (default
    all nodes).  Rows with degree > K keep the K highest-weight neighbors
    (documented truncation; max_deg defaults to d_max = no truncation).

    Fully vectorized over rows (batched CSR index arithmetic — the seed
    per-node loop was the full-graph setup hot spot).
    """
    rows = np.arange(graph.n, dtype=np.int32) if rows is None else rows
    # `max_deg or d_max` would silently treat an explicit 0 as "uncapped"
    if max_deg is None:
        k = graph.d_max
    elif max_deg >= 1:
        k = int(max_deg)
    else:
        raise ValueError(f"to_ell: max_deg must be >= 1 (or None for "
                         f"d_max={graph.d_max}), got {max_deg}")
    m = len(rows)
    deg_all = graph.degrees
    nb, valid = neighbors_batch(graph, rows)          # [m, width]
    deg = deg_all[np.asarray(rows, np.int64)]
    cw = (1.0 / np.sqrt((deg[:, None] + 1.0) * (deg_all[nb] + 1.0))
          ).astype(np.float32)
    cw[~valid] = 0.0
    width = nb.shape[1]
    if width > k:
        # keep the K highest-weight neighbors per row (padding sorts last)
        keep = np.argpartition(-cw, k - 1, axis=1)[:, :k]
        nb = np.take_along_axis(nb, keep, axis=1)
        cw = np.take_along_axis(cw, keep, axis=1)
        valid = np.take_along_axis(valid, keep, axis=1)
        nb[~valid] = 0
    idx = np.zeros((m, k), np.int32)
    w = np.zeros((m, k), np.float32)
    idx[:, :min(width, k)] = nb[:, :k]
    w[:, :min(width, k)] = cw[:, :k]
    w_self = (1.0 / (deg + 1.0)).astype(np.float32)
    return idx, w, w_self


def full_adjacency_dense(graph: Graph) -> np.ndarray:
    """Dense Ã (n x n) with self-loops — only for small theory/test graphs
    and the Wasserstein analysis."""
    a = np.zeros((graph.n, graph.n), np.float32)
    for u in range(graph.n):
        nb = graph.neighbors(u)
        a[u, nb] = 1.0
    a[np.arange(graph.n), np.arange(graph.n)] = 1.0
    deg = graph.degrees + 1.0
    dm = 1.0 / np.sqrt(deg)
    return (a * dm[:, None]) * dm[None, :]
