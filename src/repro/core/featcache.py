"""Host-side feature-row caches for the sharded SAMPLED sources.

The full-graph featshard path (kernels/neighbor_agg/featshard.py) can
classify every gather once per bind because its ELL is static.  Sampled
sources draw a fresh fan-out every step, so their cache is the LRU
variant the ISSUE names: the engine's single Prefetcher worker thread
looks every staged batch's source-node ids up in an ``LRURowCache``
before staging, modeling which rows a device-resident cache would have
served locally vs. fetched from the owning shard.  The counters feed the
same ``History.counters`` / bench columns as the full-graph plan's
bind-time stats, which is what the paper's feature-gather traffic
comparison (PAPERS.md, "Comprehensive Evaluation of GNN Training
Systems") actually needs from a CPU-mesh reproduction — the staged
arrays themselves already travel host->device per batch either way.

Single-threaded by design: ``lookup`` is only ever called from the one
Prefetcher worker (or inline when prefetch is off), so there is no lock.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.kernels.neighbor_agg.featshard import resolve_cache_rows

__all__ = ["LRURowCache", "DegreeHotRowCache", "resolve_cache_rows"]


class LRURowCache:
    """LRU set of feature-row ids with hit/miss accounting.

    ``capacity`` rows; 0 means no cache (every reference is a miss).
    ``row_bytes`` prices a miss for the remote-gather byte counter
    (feat_dim * itemsize).  Each id in a ``lookup`` batch is counted
    once per REFERENCE (duplicates within a batch hit after the first
    touch, exactly like repeated gathers within a fan-out level).
    """

    def __init__(self, capacity: int, row_bytes: int = 0):
        self.capacity = int(capacity)
        self.row_bytes = int(row_bytes)
        self.hits = 0
        self.misses = 0
        self._rows: OrderedDict = OrderedDict()

    def lookup(self, ids) -> int:
        """Touch every id in order; returns this batch's miss count."""
        ids = np.asarray(ids).reshape(-1)
        rows = self._rows
        misses = 0
        if self.capacity <= 0:
            misses = int(ids.size)
            self.misses += misses
            return misses
        for i in ids.tolist():
            if i in rows:
                rows.move_to_end(i)
                self.hits += 1
            else:
                misses += 1
                rows[i] = True
                if len(rows) > self.capacity:
                    rows.popitem(last=False)
        self.misses += misses
        return misses

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "feat_cache_rows": self.capacity,
            "feat_cache_hits": self.hits,
            "feat_cache_misses": self.misses,
            "feat_cache_hit_rate": self.hits / total if total else 1.0,
            "feat_remote_gather_bytes": self.misses * self.row_bytes,
        }


class DegreeHotRowCache:
    """Static top-C-by-degree membership cache — the host twin of the
    full-graph plan's hot set, for callers that want degree-pinned (not
    recency) accounting over sampled batches."""

    def __init__(self, degrees, capacity: int, row_bytes: int = 0):
        degrees = np.asarray(degrees)
        self.capacity = int(capacity)
        self.row_bytes = int(row_bytes)
        order = np.argsort(-degrees.astype(np.float64), kind="stable")
        self._hot = np.zeros(degrees.shape[0], bool)
        self._hot[order[: self.capacity]] = True
        self.hits = 0
        self.misses = 0

    def lookup(self, ids) -> int:
        ids = np.asarray(ids).reshape(-1)
        hot = self._hot[ids]
        h = int(hot.sum())
        self.hits += h
        misses = int(ids.size - h)
        self.misses += misses
        return misses

    stats = LRURowCache.stats
