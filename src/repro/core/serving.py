"""Batched node-classification serving over an ``EmbeddingStore``.

``GNNServer`` is the query front of the inference tier: callers submit
node-id queries from any thread; a single batcher thread coalesces them
into micro-batches (up to ``max_batch`` queried nodes, or whatever has
arrived within ``max_wait_ms`` of the first request) and answers each
batch with ONE final-layer table lookup + argmax.  Because the store
caches layer-wise embeddings, serving cost is O(queried nodes) — no
fan-out tree, no per-query forward pass; the exponential-neighborhood
cost was paid once at build time (docs/training_api.md "Inference &
serving").

Write-safe serving (PR 10, docs/training_api.md "Serving under
writes"):

- Every batch answers from the store's current immutable
  ``TableSnapshot`` via ``predict_meta`` — never from half-refreshed
  tables — and carries ``(snapshot_version, staleness_s)`` back to the
  caller (``submit(..., with_meta=True)`` → ``ServedAnswer``).
- ``max_staleness_s`` is a HARD serving SLO: when the snapshot is
  older than the bound (relative to the oldest unapplied update), the
  batcher forces a synchronous ``refresh_with_recovery`` before
  answering.  The default ``0.0`` reproduces the pre-PR-10 behavior —
  any pending update refreshes before the next batch; ``None`` never
  refreshes on the serve path (pair it with the store's background
  scheduler).
- Overload protection: ``queue_depth`` bounds the request queue;
  admission past the cap either fast-fails with
  ``ServerOverloadedError`` (``overload="fail"``) or blocks up to
  ``submit_timeout_s`` then fails (``overload="block"``).  Per-request
  deadlines (``deadline_s`` / ``default_deadline_s``) shed requests
  already expired BEFORE any table work, failing their futures with
  ``DeadlineExceededError``.
- ``close()`` never leaks futures: queued-but-unserved requests are
  drained and failed with ``RuntimeError("server closed")``, and the
  ``submit``-vs-close race is closed by taking the admission lock in
  both.

``stats()`` exposes the counters the sweep's inference axis and the
serve benchmarks record: request p50/p99/mean latency (ms, from a
fixed-size reservoir — exact up to ``stats_reservoir`` requests,
uniform sampling beyond), answered queries/s, batch counts and mean
occupancy, plus the serving SLO columns (last/max served staleness,
snapshot version, shed/overload/forced-refresh counts).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import namedtuple
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import faults
from repro.core.embedding_store import EmbeddingStore

_STOP = object()


class ServerOverloadedError(RuntimeError):
    """Admission control rejected the request: the bounded request
    queue stayed full past the configured patience."""


class DeadlineExceededError(RuntimeError):
    """The request's deadline passed before the batcher reached it; it
    was shed without spending a table lookup."""


ServedAnswer = namedtuple("ServedAnswer",
                          ["preds", "snapshot_version", "staleness_s"])


class _Reservoir:
    """Fixed-size uniform sample of a float stream (Vitter's
    algorithm R): exact below ``cap`` observations, each later
    observation replaces a uniformly random slot with probability
    cap/n — bounded memory under days-long traffic while keeping the
    percentile estimates unbiased.  NOT thread-safe: callers hold the
    owning ``ServeStats`` lock."""

    def __init__(self, cap: int = 4096, seed: int = 0):
        self.cap = max(1, int(cap))
        self.n = 0
        self._buf: List[float] = []
        self._rng = np.random.default_rng(seed)

    def add(self, x: float) -> None:
        self.n += 1
        if len(self._buf) < self.cap:
            self._buf.append(x)
        else:
            j = int(self._rng.integers(0, self.n))
            if j < self.cap:
                self._buf[j] = x

    def values(self) -> np.ndarray:
        return np.asarray(self._buf, np.float64)


class ServeStats:
    """Thread-safe latency/throughput/SLO counters (bounded memory)."""

    def __init__(self, reservoir: int = 4096):
        self._lock = threading.Lock()
        self._lat = _Reservoir(reservoir)
        self.n_requests = 0
        self.n_queries = 0
        self.n_batches = 0
        self.n_shed = 0
        self.n_overload = 0
        self.n_forced_refresh = 0
        self._version = 0
        self._staleness_last = 0.0
        self._staleness_max = 0.0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def record(self, n_requests: int, n_queries: int,
               lat_ms: Sequence[float], t0: float, t1: float, *,
               version: Optional[int] = None,
               staleness_s: Optional[float] = None) -> None:
        with self._lock:
            self.n_requests += n_requests
            self.n_queries += n_queries
            self.n_batches += 1
            for x in lat_ms:
                self._lat.add(x)
            if version is not None:
                self._version = version
            if staleness_s is not None:
                self._staleness_last = staleness_s
                self._staleness_max = max(self._staleness_max,
                                          staleness_s)
            if self._t_first is None:
                self._t_first = t0
            self._t_last = t1

    def record_shed(self, n: int = 1) -> None:
        with self._lock:
            self.n_shed += n

    def record_overload(self) -> None:
        with self._lock:
            self.n_overload += 1

    def record_forced_refresh(self) -> None:
        with self._lock:
            self.n_forced_refresh += 1

    def snapshot(self) -> Dict:
        with self._lock:
            lat = self._lat.values()
            span = ((self._t_last - self._t_first)
                    if self._t_first is not None else 0.0)
            return {
                "n_requests": self.n_requests,
                "n_queries": self.n_queries,
                "n_batches": self.n_batches,
                "mean_batch_queries": (self.n_queries / self.n_batches
                                       if self.n_batches else 0.0),
                "p50_ms": float(np.percentile(lat, 50)) if lat.size else 0.0,
                "p99_ms": float(np.percentile(lat, 99)) if lat.size else 0.0,
                "mean_ms": float(lat.mean()) if lat.size else 0.0,
                "qps": (self.n_queries / span) if span > 0 else 0.0,
                "snapshot_version": self._version,
                "staleness_last_s": self._staleness_last,
                "staleness_max_s": self._staleness_max,
                "n_shed": self.n_shed,
                "n_overload": self.n_overload,
                "n_forced_refresh": self.n_forced_refresh,
            }


class _Request:
    __slots__ = ("nodes", "future", "t", "deadline_t", "with_meta")

    def __init__(self, nodes: np.ndarray,
                 deadline_t: Optional[float] = None,
                 with_meta: bool = False):
        self.nodes = nodes
        self.future: "Future[np.ndarray]" = Future()
        self.t = time.monotonic()
        self.deadline_t = deadline_t
        self.with_meta = with_meta


class GNNServer:
    """Micro-batching query server over a built ``EmbeddingStore``.

    ``start=False`` defers the batcher thread (requests queue up and
    coalesce deterministically once ``start()`` runs — used by the
    batching tests); default is to start immediately.

    ``refresh_every_updates`` / ``refresh_budget_ms`` start the store's
    background refresh scheduler (owned by this server: stopped on
    ``close()``); the serve-path ``max_staleness_s`` bound stays the
    hard backstop either way."""

    def __init__(self, store: EmbeddingStore, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, start: bool = True,
                 queue_depth: Optional[int] = None,
                 overload: str = "block",
                 submit_timeout_s: float = 1.0,
                 default_deadline_s: Optional[float] = None,
                 max_staleness_s: Optional[float] = 0.0,
                 refresh_every_updates: Optional[int] = None,
                 refresh_budget_ms: Optional[float] = None,
                 refresh_retries: int = 2,
                 refresh_backoff_s: float = 0.02,
                 stats_reservoir: int = 4096):
        if overload not in ("block", "fail"):
            raise ValueError(f"overload={overload!r} (want block|fail)")
        self.store = store
        self.max_batch = max(1, int(max_batch))
        self.max_wait_ms = float(max_wait_ms)
        self.queue_depth = (None if queue_depth is None
                            else max(1, int(queue_depth)))
        self.overload = overload
        self.submit_timeout_s = float(submit_timeout_s)
        self.default_deadline_s = default_deadline_s
        self.max_staleness_s = max_staleness_s
        self.refresh_retries = int(refresh_retries)
        self.refresh_backoff_s = float(refresh_backoff_s)
        self.serve_stats = ServeStats(stats_reservoir)
        self._q: "queue.Queue" = queue.Queue(maxsize=self.queue_depth or 0)
        self._lock = threading.Lock()       # admission: submit vs close
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        self._owns_scheduler = False
        if refresh_every_updates is not None or refresh_budget_ms is not None:
            store.start_scheduler(
                refresh_every_updates=refresh_every_updates,
                refresh_budget_ms=refresh_budget_ms,
                max_staleness_s=max_staleness_s,
                max_retries=self.refresh_retries,
                backoff_s=self.refresh_backoff_s)
            self._owns_scheduler = True
        if start:
            self.start()

    def start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------
    # client side
    # ------------------------------------------------------------------
    def submit(self, nodes, *, deadline_s: Optional[float] = None,
               with_meta: bool = False) -> "Future[np.ndarray]":
        """Enqueue a query for ``nodes``; resolves to int predictions
        aligned with the request order (or a ``ServedAnswer`` with SLO
        metadata when ``with_meta=True``).

        Raises ``ServerOverloadedError`` when the bounded queue stays
        full (immediately under ``overload="fail"``, after
        ``submit_timeout_s`` under ``"block"``); an expired
        ``deadline_s`` fails the FUTURE with ``DeadlineExceededError``
        when the batcher sheds it."""
        nodes = np.atleast_1d(np.asarray(nodes, np.int64))
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline_t = (time.monotonic() + deadline_s
                      if deadline_s is not None else None)
        req = _Request(nodes, deadline_t, with_meta)
        with self._lock:
            if self._closed:
                raise RuntimeError("GNNServer is closed")
            if self.queue_depth is None:
                self._q.put(req)
            elif self.overload == "fail":
                try:
                    self._q.put_nowait(req)
                except queue.Full:
                    self.serve_stats.record_overload()
                    raise ServerOverloadedError(
                        f"request queue full (depth={self.queue_depth})"
                    ) from None
            else:
                try:
                    self._q.put(req, timeout=self.submit_timeout_s)
                except queue.Full:
                    self.serve_stats.record_overload()
                    raise ServerOverloadedError(
                        f"request queue full (depth={self.queue_depth}) "
                        f"after {self.submit_timeout_s}s") from None
        return req.future

    def classify(self, nodes, timeout: Optional[float] = 30.0
                 ) -> np.ndarray:
        """Blocking ``submit``."""
        return self.submit(nodes).result(timeout=timeout)

    def stats(self) -> Dict:
        return self.serve_stats.snapshot()

    def close(self, timeout: float = 5.0) -> None:
        """Stop the batcher (and the store scheduler, if this server
        started it), then fail every still-queued request's future with
        ``RuntimeError("server closed")`` — callers never hang on a
        future the server will no longer serve."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        try:
            self._q.put_nowait(_STOP)
        except queue.Full:
            pass                     # batcher's idle timeout sees _closed
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        if self._owns_scheduler:
            self.store.stop_scheduler()
        while True:                  # drain leftovers (batcher is gone)
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is _STOP:
                continue
            if not item.future.done():
                item.future.set_exception(RuntimeError("server closed"))

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # batcher thread
    # ------------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if self._closed:
                    return
                continue
            if item is _STOP:
                return
            batch = [item]
            n = len(item.nodes)
            deadline = item.t + self.max_wait_ms / 1000.0
            stop = False
            while n < self.max_batch:
                wait = deadline - time.monotonic()
                if wait <= 0:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        break
                else:
                    try:
                        nxt = self._q.get(timeout=wait)
                    except queue.Empty:
                        break
                if nxt is _STOP:
                    stop = True
                    break
                batch.append(nxt)
                n += len(nxt.nodes)
            self._serve(batch)
            if stop:
                return

    def _needs_refresh(self) -> bool:
        """Hard staleness SLO: refresh before answering iff there is no
        snapshot yet, or pending updates have aged past
        ``max_staleness_s`` (``None`` → never on the serve path)."""
        if self.store.snapshot() is None:
            return True
        if self.max_staleness_s is None:
            return False
        return (self.store.dirty
                and self.store.staleness_s() >= self.max_staleness_s)

    def _serve(self, batch: List[_Request]) -> None:
        t0 = time.monotonic()
        # shed expired requests BEFORE spending refresh/lookup work
        live = []
        for r in batch:
            if r.deadline_t is not None and t0 > r.deadline_t:
                r.future.set_exception(DeadlineExceededError(
                    f"deadline passed {t0 - r.deadline_t:.3f}s before "
                    "serving"))
                self.serve_stats.record_shed()
            else:
                live.append(r)
        if not live:
            return
        try:
            # the SLO check and the refresh race benignly with writers:
            # an update landing after the check is at most one batch
            # late, and the NEXT check sees its true age
            while self._needs_refresh():
                self.store.refresh_with_recovery(
                    max_retries=self.refresh_retries,
                    backoff_s=self.refresh_backoff_s)
                self.serve_stats.record_forced_refresh()
                if self.store.snapshot() is not None:
                    break
            ids = np.concatenate([r.nodes for r in live])
            preds, version, staleness = self.store.predict_meta(ids)
            faults.maybe_crash("serve.before_reply")
            t1 = time.monotonic()
            off = 0
            lats = []
            for r in live:
                k = len(r.nodes)
                p = preds[off:off + k]
                r.future.set_result(
                    ServedAnswer(p, version, staleness)
                    if r.with_meta else p)
                off += k
                lats.append((t1 - r.t) * 1000.0)
            self.serve_stats.record(len(live), len(ids), lats, t0, t1,
                                    version=version,
                                    staleness_s=staleness)
        except BaseException as e:               # surface on the futures
            for r in live:
                if not r.future.done():
                    r.future.set_exception(e)
