"""Batched node-classification serving over an ``EmbeddingStore``.

``GNNServer`` is the query front of the inference tier: callers submit
node-id queries from any thread; a single batcher thread coalesces them
into micro-batches (up to ``max_batch`` queried nodes, or whatever has
arrived within ``max_wait_ms`` of the first request) and answers each
batch with ONE final-layer table lookup + argmax.  Because the store
caches layer-wise embeddings, serving cost is O(queried nodes) — no
fan-out tree, no per-query forward pass; the exponential-neighborhood
cost was paid once at build time (docs/training_api.md "Inference &
serving").

Dirty stores refresh lazily ON the batcher thread (``store.predict``
auto-refreshes), so a graph update delays only the first batch after
it, by the incremental re-embed cost.

``stats()`` exposes the counters the sweep's inference axis and the
serve benchmarks record: request p50/p99/mean latency (ms), answered
queries/s, batch counts and mean occupancy.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.embedding_store import EmbeddingStore

_STOP = object()


class ServeStats:
    """Thread-safe latency/throughput counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._lat_ms: List[float] = []
        self.n_requests = 0
        self.n_queries = 0
        self.n_batches = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def record(self, n_requests: int, n_queries: int,
               lat_ms: Sequence[float], t0: float, t1: float) -> None:
        with self._lock:
            self.n_requests += n_requests
            self.n_queries += n_queries
            self.n_batches += 1
            self._lat_ms.extend(lat_ms)
            if self._t_first is None:
                self._t_first = t0
            self._t_last = t1

    def snapshot(self) -> Dict:
        with self._lock:
            lat = np.asarray(self._lat_ms, np.float64)
            span = ((self._t_last - self._t_first)
                    if self._t_first is not None else 0.0)
            return {
                "n_requests": self.n_requests,
                "n_queries": self.n_queries,
                "n_batches": self.n_batches,
                "mean_batch_queries": (self.n_queries / self.n_batches
                                       if self.n_batches else 0.0),
                "p50_ms": float(np.percentile(lat, 50)) if lat.size else 0.0,
                "p99_ms": float(np.percentile(lat, 99)) if lat.size else 0.0,
                "mean_ms": float(lat.mean()) if lat.size else 0.0,
                "qps": (self.n_queries / span) if span > 0 else 0.0,
            }


class _Request:
    __slots__ = ("nodes", "future", "t")

    def __init__(self, nodes: np.ndarray):
        self.nodes = nodes
        self.future: "Future[np.ndarray]" = Future()
        self.t = time.perf_counter()


class GNNServer:
    """Micro-batching query server over a built ``EmbeddingStore``.

    ``start=False`` defers the batcher thread (requests queue up and
    coalesce deterministically once ``start()`` runs — used by the
    batching tests); default is to start immediately."""

    def __init__(self, store: EmbeddingStore, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, start: bool = True):
        self.store = store
        self.max_batch = max(1, int(max_batch))
        self.max_wait_ms = float(max_wait_ms)
        self.serve_stats = ServeStats()
        self._q: "queue.Queue" = queue.Queue()
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    def start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------
    # client side
    # ------------------------------------------------------------------
    def submit(self, nodes) -> "Future[np.ndarray]":
        """Enqueue a query for ``nodes``; resolves to int predictions
        aligned with the request order."""
        if self._closed:
            raise RuntimeError("GNNServer is closed")
        nodes = np.atleast_1d(np.asarray(nodes, np.int64))
        req = _Request(nodes)
        self._q.put(req)
        return req.future

    def classify(self, nodes, timeout: Optional[float] = 30.0
                 ) -> np.ndarray:
        """Blocking ``submit``."""
        return self.submit(nodes).result(timeout=timeout)

    def stats(self) -> Dict:
        return self.serve_stats.snapshot()

    def close(self, timeout: float = 5.0) -> None:
        """Drain queued requests, then stop the batcher."""
        if self._closed:
            return
        self._closed = True
        self._q.put(_STOP)
        if self._thread is not None:
            self._thread.join(timeout=timeout)

    def __enter__(self):
        self.start()
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ------------------------------------------------------------------
    # batcher thread
    # ------------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is _STOP:
                return
            batch = [item]
            n = len(item.nodes)
            deadline = item.t + self.max_wait_ms / 1000.0
            stop = False
            while n < self.max_batch:
                wait = deadline - time.perf_counter()
                if wait <= 0:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        break
                else:
                    try:
                        nxt = self._q.get(timeout=wait)
                    except queue.Empty:
                        break
                if nxt is _STOP:
                    stop = True
                    break
                batch.append(nxt)
                n += len(nxt.nodes)
            self._serve(batch)
            if stop:
                return

    def _serve(self, batch: List[_Request]) -> None:
        t0 = time.perf_counter()
        try:
            ids = np.concatenate([r.nodes for r in batch])
            preds = self.store.predict(ids)       # auto-refresh if dirty
            t1 = time.perf_counter()
            off = 0
            lats = []
            for r in batch:
                k = len(r.nodes)
                r.future.set_result(preds[off:off + k])
                off += k
                lats.append((t1 - r.t) * 1000.0)
            self.serve_stats.record(len(batch), len(ids), lats, t0, t1)
        except BaseException as e:               # surface on the futures
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
