"""Full-graph GD vs mini-batch SGD training loops (the paper's two
paradigms) with identical model code and metric recording.

Full-graph: GD over all training nodes each iteration, ELL layout.
Mini-batch: per-iteration (b, β)-sampled fan-out trees, SGD.
Both record History for iteration-to-loss / iteration-to-accuracy /
time-to-accuracy / throughput (§5.1).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core import gnn as G
from repro.core.graph import Graph, to_ell
from repro.core.metrics import History
from repro.core.prefetch import Prefetcher
from repro.core.sampler import FanoutBatch, expand_batch, gather_features, \
    sample_batch
from repro.optim import sgd


@dataclasses.dataclass
class TrainResult:
    params: list
    history: History
    final_test_acc: float


def _device_ell(graph: Graph, max_deg: Optional[int] = None):
    """Device-resident ELL layout, memoized per graph: evaluation and the
    full-loss tracker used to rebuild (re-pad + re-upload) it on every
    call.  The cache lives on the Graph instance so it dies with it."""
    key = int(max_deg or graph.d_max)
    cache = getattr(graph, "_ell_cache", None)
    if cache is None:
        cache = {}
        object.__setattr__(graph, "_ell_cache", cache)
    if "base" not in cache:                  # max_deg-independent uploads
        cache["base"] = (jnp.asarray(graph.feats),
                         jnp.asarray(graph.labels))
    if key not in cache:
        idx, w, w_self = to_ell(graph, max_deg=max_deg)
        cache[key] = (jnp.asarray(idx), jnp.asarray(w), jnp.asarray(w_self))
    return cache[key] + cache["base"]


def evaluate_full(params, cfg: GNNConfig, graph: Graph, ell, nodes
                  ) -> float:
    """Inference uses ALL neighbors across the entire graph (§4.1)."""
    idx, w, w_self, feats, labels = ell
    logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self)
    sel = jnp.asarray(nodes)
    return float(G.accuracy(logits[sel], labels[sel]))


def train_full_graph(graph: Graph, cfg: GNNConfig, lr: float,
                     n_iters: int, eval_every: int = 10, seed: int = 0,
                     target_loss: Optional[float] = None,
                     max_deg: Optional[int] = None) -> TrainResult:
    """Paper's full-graph paradigm: GD on all n_train nodes, Ã_train^full."""
    ell = _device_ell(graph, max_deg)
    idx, w, w_self, feats, labels = ell
    train_nodes = jnp.asarray(graph.train_nodes)
    key = jax.random.key(seed)
    params = G.init_gnn(key, cfg, graph.feats.shape[1])
    opt = sgd(lr)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state):
        def loss_fn(p):
            logits = G.full_graph_forward(p, cfg, feats, idx, w, w_self)
            lt = logits[train_nodes]
            return G.gnn_loss(lt, labels[train_nodes], cfg.loss,
                              cfg.n_classes)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    hist = History()
    hist.start()
    n_train = len(graph.train_nodes)
    for it in range(n_iters):
        params, opt_state, loss = step(params, opt_state)
        val = (evaluate_full(params, cfg, graph, ell, graph.val_nodes)
               if it % eval_every == 0 else None)
        hist.record(float(loss), val, nodes=n_train)
        # full-graph training: the per-iteration loss IS the full loss
        hist.full_losses.append(float(loss))
        hist.full_loss_iters.append(it + 1)
        if target_loss is not None and float(loss) <= target_loss:
            break
    acc = evaluate_full(params, cfg, graph, ell, graph.test_nodes)
    return TrainResult(params, hist, acc)


def _batch_to_device(graph: Graph, batch: FanoutBatch, host_feats=None):
    """host_feats: pre-gathered hop features (from the Prefetcher thread);
    gathered inline when absent."""
    if host_feats is None:
        host_feats = gather_features(graph, batch)
    feats = [jnp.asarray(f) for f in host_feats]
    masks = [jnp.asarray(m.astype(np.float32)) for m in batch.masks]
    weights = [jnp.asarray(wt) for wt in batch.weights]
    self_w = [jnp.asarray(s) for s in batch.self_w]
    return feats, masks, weights, self_w, jnp.asarray(batch.labels)


def train_minibatch(graph: Graph, cfg: GNNConfig, lr: float, n_iters: int,
                    batch_size: Optional[int] = None,
                    fanouts: Optional[Sequence[int]] = None,
                    eval_every: int = 10, seed: int = 0,
                    target_loss: Optional[float] = None,
                    track_full_loss_every: int = 0,
                    prefetch: bool = True) -> TrainResult:
    """Paper's mini-batch paradigm: per-iteration (b, β) sampling + SGD.
    Host-side sampling emulates the CPU-side loaders of DGL/PyG; with
    `prefetch` it runs on a background thread, double-buffered ahead of
    the device step (same batch sequence as the synchronous path)."""
    b = batch_size or cfg.batch_size
    fanouts = tuple(fanouts or cfg.fanout)
    assert len(fanouts) == cfg.n_layers
    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)
    params = G.init_gnn(key, cfg, graph.feats.shape[1])
    opt = sgd(lr)
    opt_state = opt.init(params)
    ell = _device_ell(graph)   # for evaluation only

    @jax.jit
    def step(params, opt_state, feats, masks, weights, self_w, labels):
        def loss_fn(p):
            logits = G.minibatch_forward(p, cfg, feats, masks, weights,
                                         self_w)
            return G.gnn_loss(logits, labels, cfg.loss, cfg.n_classes)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    train_sel = jnp.asarray(graph.train_nodes)
    idx_e, w_e, ws_e, feats_e, labels_e = ell

    @jax.jit
    def full_loss(params):
        logits = G.full_graph_forward(params, cfg, feats_e, idx_e, w_e,
                                      ws_e)
        return G.gnn_loss(logits[train_sel], labels_e[train_sel], cfg.loss,
                          cfg.n_classes)

    pf = (Prefetcher(graph, b, fanouts, seed=seed, n_batches=n_iters)
          if prefetch else None)
    hist = History()
    hist.start()
    try:
        for it in range(n_iters):
            if pf is not None:
                fb, host_feats = pf.next()
            else:
                fb = sample_batch(rng, graph, b, fanouts)
                host_feats = None
            feats, masks, weights, self_w, labels = _batch_to_device(
                graph, fb, host_feats)
            params, opt_state, loss = step(params, opt_state, feats, masks,
                                           weights, self_w, labels)
            val = (evaluate_full(params, cfg, graph, ell, graph.val_nodes)
                   if it % eval_every == 0 else None)
            hist.record(float(loss), val, nodes=fb.batch_size)
            if track_full_loss_every and it % track_full_loss_every == 0:
                hist.full_losses.append(float(full_loss(params)))
                hist.full_loss_iters.append(it + 1)
            if target_loss is not None and float(loss) <= target_loss:
                break
    finally:
        if pf is not None:
            pf.close()
    acc = evaluate_full(params, cfg, graph, ell, graph.test_nodes)
    return TrainResult(params, hist, acc)


def full_graph_train_loss(graph: Graph, params, cfg: GNNConfig,
                          ell=None) -> float:
    """Loss of the CURRENT params on the full training set — the paper
    evaluates mini-batch convergence against the full-graph objective.
    `_device_ell` memoizes per graph, so repeated calls (every
    `track_full_loss_every` iterations) no longer rebuild the ELL;
    callers holding a prebuilt ELL can pass it directly."""
    if ell is None:
        ell = _device_ell(graph)
    idx, w, w_self, feats, labels = ell
    logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self)
    sel = jnp.asarray(graph.train_nodes)
    return float(G.gnn_loss(logits[sel], labels[sel], cfg.loss,
                            cfg.n_classes))
