"""Legacy entry points for the paper's two paradigms, now thin wrappers
over the unified engine in ``repro.core.engine``.

Full-graph: GD over all training nodes each iteration, ELL layout —
``Trainer`` + ``FullGraphSource`` (the (b=n, beta=d_max) limit case).
Mini-batch: per-iteration (b, β)-sampled fan-out trees + SGD —
``Trainer`` + ``SampledSource``.

Both reproduce the pre-engine loops' loss/History sequences bit-for-bit
at fixed seed (test-enforced against tests/goldens/trainer_seed.json).
Prefer the engine API (``Trainer``, ``TrainPlan``, ``BatchSource``,
callbacks) and ``repro.core.experiment`` for new code — see
docs/training_api.md.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.core.engine import (FullGraphSource, SampledSource, Trainer,
                               TrainPlan, TrainResult, _device_ell,
                               evaluate_full)
from repro.core.graph import Graph

__all__ = ["TrainResult", "train_full_graph", "train_minibatch",
           "evaluate_full", "full_graph_train_loss"]


def train_full_graph(graph: Graph, cfg: GNNConfig, lr: float,
                     n_iters: int, eval_every: int = 10, seed: int = 0,
                     target_loss: Optional[float] = None,
                     max_deg: Optional[int] = None) -> TrainResult:
    """Paper's full-graph paradigm: GD on all n_train nodes, Ã_train^full."""
    plan = TrainPlan(lr=lr, n_iters=n_iters, eval_every=eval_every,
                     seed=seed, target_loss=target_loss)
    return Trainer(graph, cfg, plan,
                   source=FullGraphSource(max_deg=max_deg)).run()


def train_minibatch(graph: Graph, cfg: GNNConfig, lr: float, n_iters: int,
                    batch_size: Optional[int] = None,
                    fanouts: Optional[Sequence[int]] = None,
                    eval_every: int = 10, seed: int = 0,
                    target_loss: Optional[float] = None,
                    track_full_loss_every: int = 0,
                    prefetch: bool = True) -> TrainResult:
    """Paper's mini-batch paradigm: per-iteration (b, β) sampling + SGD.
    Host-side sampling emulates the CPU-side loaders of DGL/PyG; with
    `prefetch` it runs on a background thread, double-buffered ahead of
    the device step (same batch sequence as the synchronous path)."""
    plan = TrainPlan(lr=lr, n_iters=n_iters, eval_every=eval_every,
                     seed=seed, target_loss=target_loss,
                     track_full_loss_every=track_full_loss_every)
    source = SampledSource(batch_size=batch_size, fanouts=fanouts,
                           prefetch=prefetch)
    return Trainer(graph, cfg, plan, source=source).run()


def full_graph_train_loss(graph: Graph, params, cfg: GNNConfig,
                          ell=None) -> float:
    """Loss of the CURRENT params on the full training set — the paper
    evaluates mini-batch convergence against the full-graph objective.
    `_device_ell` memoizes per graph, so repeated calls (every
    `track_full_loss_every` iterations) no longer rebuild the ELL;
    callers holding a prebuilt ELL can pass it directly."""
    from repro.core import gnn as G
    if ell is None:
        ell = _device_ell(graph)
    idx, w, w_self, feats, labels = ell
    logits = G.full_graph_forward(params, cfg, feats, idx, w, w_self)
    sel = jnp.asarray(graph.train_nodes)
    return float(G.gnn_loss(logits[sel], labels[sel], cfg.loss,
                            cfg.n_classes))
