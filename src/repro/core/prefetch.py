"""Async mini-batch prefetch pipeline (DGL-dataloader style), supervised.

The paper attributes the mini-batch paradigm's per-iteration overhead to
CPU-side sampling + feature loading (§5 throughput analysis).  Overlapping
that host work with the device step hides it almost entirely: a background
thread runs sample -> gather and double-buffers the results in a bounded
queue while the accelerator consumes the previous batch.

Batches are produced by ONE thread from ONE rng, in order, so a run with
`Prefetcher` consumes the identical batch sequence as the synchronous
sample-in-the-loop path with the same seed.

Fault tolerance (docs/training_api.md "Fault tolerance"):

- worker errors are CLASSIFIED: exception types in ``transient`` (by
  default ``faults.TransientSamplerFault`` plus ``MemoryError``) get the
  worker restarted with bounded exponential backoff — the rng is rewound
  to the snapshot taken before the failed draw, so the replacement
  worker REPLAYS the same batch and the consumed sequence is identical
  to a fault-free run (test-enforced).  Anything else is FATAL: stored
  and re-raised from ``next()``.
- ``next()`` after the end-of-stream sentinel (or a fatal error) has
  been consumed re-raises ``StopIteration`` / the stored error
  IMMEDIATELY instead of blocking forever on the drained queue (the
  pre-PR-6 deadlock).
- every delivered batch carries the rng state captured AFTER its draw
  (``last_rng_state``), and a Prefetcher can be constructed from such a
  state (``rng_state=``) — the exact-resume hook: a restored run's
  batch stream continues bit-for-bit where the checkpoint left off.
"""
from __future__ import annotations

import queue
import sys
import threading
import time
import traceback
import warnings
from typing import List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core import faults
from repro.core.graph import Graph
from repro.core.sampler import FanoutBatch, gather_features, sample_batch

#: worker exceptions restarted-with-backoff instead of surfaced
DEFAULT_TRANSIENT: Tuple[Type[BaseException], ...] = (
    faults.TransientSamplerFault, MemoryError)


class HostStagingRing:
    """Reusable host-side staging buffers for device uploads.

    Mini-batch shapes are constant across iterations (b and the fan-outs
    are fixed), so the host arrays feeding ``jax.device_put`` can be
    allocated ONCE per shape and recycled instead of freshly allocated
    every batch — the host-memory analogue of pinned-buffer reuse on
    GPU/TPU loaders (ROADMAP "pin + reuse device buffers" follow-up; true
    ``donate_argnums`` device-buffer donation is the real-TPU extension).

    ``acquire()`` hands out a free slot; ``buffers(slot, specs)`` returns
    the slot's once-allocated buffers for producers to FILL in place
    (``np.take(..., out=)`` gathers, in-place dtype casts — no per-batch
    allocation and no extra copy); ``release(slot)`` makes the slot
    reusable once the consuming step has synced.  Under the engine's
    deferred loss sync that release lags ONE extra step (records are
    read back after the next step dispatches), so the engine sizes the
    ring one slot larger.  Slot handout is a blocking queue, so a
    producer that runs ahead of ``release`` backpressures instead of
    overwriting in-flight data.  Thread-safe: acquire/release may run on
    different threads; ``close()`` wakes any blocked ``acquire``.
    """

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self._free: "queue.Queue[int]" = queue.Queue()
        for i in range(n_slots):
            self._free.put(i)
        self._bufs = {}          # slot -> flat list of staging ndarrays
        self._closed = False

    def acquire(self) -> int:
        while True:
            try:
                return self._free.get(timeout=0.1)
            except queue.Empty:
                if self._closed:
                    raise RuntimeError("HostStagingRing closed")

    def buffers(self, slot: int, specs) -> List[np.ndarray]:
        """The slot's buffers for ``specs`` = [(shape, dtype), ...] —
        allocated on first use, reused verbatim while specs match."""
        bufs = self._bufs.get(slot)
        if bufs is None or len(bufs) != len(specs) or any(
                b.shape != tuple(s) or b.dtype != np.dtype(d)
                for b, (s, d) in zip(bufs, specs)):
            bufs = [np.empty(s, d) for s, d in specs]
            self._bufs[slot] = bufs
        return bufs

    def close(self) -> None:
        self._closed = True

    def release(self, slot: int) -> None:
        self._free.put(slot)


class Prefetcher:
    """Supervised double-buffered background sampler + feature gather.

    Yields (FanoutBatch, payload) tuples, where payload is the gathered
    hop features by default; `payload_fn(graph, fb)` overrides the
    per-batch host work so callers can move feature gather + staging
    onto this background thread (see `engine.SampledSource`).
    `sample_fn(rng, graph, batch_size, fanouts)` overrides how batches
    are drawn (same signature as `sample_batch`, the default) so
    scenario sources — cluster unions, importance-weighted targets —
    keep the one-thread/one-rng ordering guarantee.  `depth` is the
    queue bound (2 = classic double buffering: one batch in flight on
    the host while the device consumes the other).

    `max_restarts` bounds how many transient worker deaths are absorbed
    (each restart replays the failed batch from the pre-draw rng
    snapshot after an exponential-backoff pause of
    ``backoff * 2**attempt``, capped at ``backoff_cap`` seconds);
    `transient` is the tuple of exception types classified transient.
    `rng_state` (a ``numpy`` bit-generator state dict, as exposed by
    `last_rng_state`) resumes the batch stream mid-sequence.
    """

    _SENTINEL = object()

    def __init__(self, graph: Graph, batch_size: int,
                 fanouts: Sequence[int], seed: int = 0, depth: int = 2,
                 n_batches: Optional[int] = None,
                 payload_fn=None, sample_fn=None,
                 max_restarts: int = 3,
                 backoff: float = 0.05, backoff_cap: float = 2.0,
                 transient: Tuple[Type[BaseException], ...]
                 = DEFAULT_TRANSIENT,
                 rng_state: Optional[dict] = None):
        self.graph = graph
        self.batch_size = batch_size
        self.fanouts = tuple(fanouts)
        self.n_batches = n_batches
        self.payload_fn = payload_fn or gather_features
        self.sample_fn = sample_fn or sample_batch
        self.max_restarts = max_restarts
        self.backoff = backoff
        self.backoff_cap = backoff_cap
        self.transient = tuple(transient)
        self._q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._rng = np.random.default_rng(seed)
        if rng_state is not None:
            self._rng.bit_generator.state = rng_state
        #: rng state after the draw of the most recently DELIVERED batch
        #: (feed back in as ``rng_state=`` to resume the sequence there)
        self.last_rng_state: Optional[dict] = rng_state
        #: completed transient restarts so far
        self.restarts = 0
        self._produced = 0               # survives worker restarts
        self._finished = False           # end-of-stream sentinel consumed
        self._pre_draw_state: Optional[dict] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def _produce_loop(self):
        while not self._stop.is_set():
            if self.n_batches is not None \
                    and self._produced >= self.n_batches:
                return
            # snapshot BEFORE the draw: a transient failure anywhere in
            # sample/payload rewinds here, so the restarted worker
            # replays this very batch and ordering is preserved
            self._pre_draw_state = self._rng.bit_generator.state
            fb = self.sample_fn(self._rng, self.graph,
                                self.batch_size, self.fanouts)
            payload = self.payload_fn(self.graph, fb)
            post_state = self._rng.bit_generator.state
            # blocking put with timeout so close() can interrupt
            while not self._stop.is_set():
                try:
                    self._q.put((fb, payload, post_state), timeout=0.1)
                    break
                except queue.Full:
                    continue
            else:
                return
            self._produced += 1

    def _worker(self):
        try:
            self._produce_loop()
        except self.transient as e:
            if self.restarts < self.max_restarts \
                    and not self._stop.is_set():
                self.restarts += 1
                delay = min(self.backoff * (2 ** (self.restarts - 1)),
                            self.backoff_cap)
                warnings.warn(
                    f"Prefetcher worker hit transient "
                    f"{type(e).__name__}: {e} — restart "
                    f"{self.restarts}/{self.max_restarts} in "
                    f"{delay:.2f}s (batch {self._produced} will be "
                    f"replayed)", RuntimeWarning, stacklevel=2)
                if self._stop.wait(delay):      # closed during backoff
                    self._put_sentinel()
                    return
                if self._pre_draw_state is not None:
                    self._rng.bit_generator.state = self._pre_draw_state
                t = threading.Thread(target=self._worker, daemon=True)
                self._thread = t
                t.start()
                return                           # old thread retires
            # restart budget exhausted: escalate to fatal
            self._err = e
            self._put_sentinel()
        except BaseException as e:               # fatal: surfaced on next()
            self._err = e
            self._put_sentinel()
        else:
            self._put_sentinel()

    def _put_sentinel(self):
        while True:
            try:
                self._q.put(self._SENTINEL, timeout=0.1)
                break
            except queue.Full:
                if self._stop.is_set():
                    break

    # ------------------------------------------------------------------
    def next(self) -> Tuple[FanoutBatch, List[np.ndarray]]:
        if self._finished:
            # post-sentinel calls re-raise IMMEDIATELY (the stored fatal
            # error, or StopIteration) instead of blocking forever on
            # the drained queue
            if self._err is not None:
                raise self._err
            raise StopIteration
        item = self._q.get()
        if item is self._SENTINEL:
            self._finished = True
            if self._err is not None:
                raise self._err
            raise StopIteration
        fb, payload, post_state = item
        self.last_rng_state = post_state
        return fb, payload

    def __iter__(self):
        while True:
            try:
                yield self.next()
            except StopIteration:
                return

    def close(self, timeout: float = 5.0):
        self._stop.set()
        # drain so a blocked put wakes up
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            # don't return silently leaking a live thread: surface WHERE
            # the worker is stuck (it is a daemon, so it cannot block
            # interpreter exit, but a wedged sample_fn/payload_fn would
            # otherwise go unnoticed until batches stop arriving)
            frame = sys._current_frames().get(self._thread.ident)
            where = ("".join(traceback.format_stack(frame))
                     if frame is not None else "<no stack available>")
            warnings.warn(
                f"Prefetcher worker did not exit within {timeout:.1f}s of "
                f"close(); the thread is stuck in:\n{where}",
                RuntimeWarning, stacklevel=2)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
