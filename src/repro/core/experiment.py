"""First-class (b, β) experiment runner on top of the unified Trainer.

Every figure in the paper's §5 is a grid over batch size b and fan-out
size β (with full-graph GD as the (b=n, β=d_max) corner).  This module
drives those grids through the engine and emits structured rows:

    plan  = TrainPlan(lr=0.3, n_iters=200, eval_every=10)
    row   = run_experiment(graph, cfg, plan, b=256, fanouts=(10, 5))
    rows  = sweep(graph, cfg, plan, batch_sizes=[64, 256],
                  fanout_grid=[(5, 3), (10, 5)], include_fullgraph=True)
    save_rows("fig2_sweep", rows)          # JSON + CSV side by side

CLI (used by scripts/ci.sh as the per-PR sweep smoke):

    PYTHONPATH=src python -m repro.core.experiment \
        --preset arxiv-like --n 400 --iters 4 --bs 32 64 --fanout 3
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import itertools
import json
import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import GNNConfig
from repro.core import faults
from repro.core.engine import (BatchSource, Callback, ClusterSource,
                               FullGraphSource, ImportanceSampledSource,
                               SampledSource, ShardedFullGraphSource,
                               ShardedSampledSource, Trainer, TrainPlan,
                               TrainResult)
from repro.core.graph import Graph
from repro.core.metrics import (iteration_to_accuracy, iteration_to_loss,
                                iteration_to_full_loss,
                                throughput_nodes_per_sec, time_to_accuracy)

OUT_DIR = os.environ.get("BENCH_OUT", "experiments/bench")


# ---------------------------------------------------------------------------
# Single experiment
# ---------------------------------------------------------------------------

def metrics_row(res: TrainResult, target_loss: Optional[float] = None,
                target_acc: Optional[float] = None) -> Dict:
    """Metric columns for one TrainResult — the single row schema shared
    by run_experiment, sweep, and benchmarks/common.summarize."""
    h = res.history
    row: Dict = {
        "iters": len(h.losses),
        "first_loss": round(h.losses[0], 6),
        "final_loss": round(h.losses[-1], 6),
        "test_acc": round(res.final_test_acc, 6),
        "throughput_nodes_s": round(throughput_nodes_per_sec(h), 1),
        "wall_time_s": round(h.times[-1], 4) if h.times else 0.0,
        "stop_reason": res.stop_reason or "",
    }
    if target_loss is not None:
        row["iter_to_loss"] = iteration_to_loss(h, target_loss)
        if h.full_losses:
            row["iter_to_full_loss"] = iteration_to_full_loss(
                h, target_loss)
    if target_acc is not None:
        row["iter_to_acc"] = iteration_to_accuracy(h, target_acc)
        row["time_to_acc_s"] = time_to_accuracy(h, target_acc)
    return row


def inference_metrics(graph: Graph, cfg: GNNConfig, params, *,
                      serve_queries: int = 64, seed: int = 0,
                      chunk_size: Optional[int] = None,
                      mesh=None) -> Dict:
    """The sweep's INFERENCE AXIS: serving-cost columns for one trained
    model (paper extension — training configs compared by whole-pipeline
    cost, not just steps/s).  Builds the layer-wise embedding store once
    (``inference_ms_per_node``), answers ``serve_queries`` micro-batched
    8-node queries through ``GNNServer`` (``serve_p50_ms`` /
    ``serve_p99_ms`` / ``serve_qps``) and scores the cached final-layer
    logits on the test split (``serve_acc`` — full-neighborhood
    inference accuracy, the §4.1 evaluation protocol).  PR 10 adds the
    serving SLO columns next to the latency percentiles: the snapshot
    version answered from, the max served staleness, and the
    shed/forced-refresh counts (all zero in this write-free axis —
    nonzero only under the serve-under-writes benchmark)."""
    from repro.core.embedding_store import EmbeddingStore
    from repro.core.serving import GNNServer

    store = EmbeddingStore(params, cfg, graph,
                           chunk_size=chunk_size or min(graph.n, 512),
                           mesh=mesh)
    run = store.build()
    test = graph.test_nodes
    pool = test if len(test) else np.arange(graph.n)
    rng = np.random.default_rng(seed)
    server = GNNServer(store, max_batch=32, max_wait_ms=1.0)
    try:
        futs = [server.submit(rng.choice(pool, size=8))
                for _ in range(serve_queries)]
        for f in futs:
            f.result(timeout=60.0)
    finally:
        server.close()
    st = server.stats()
    acc = (float((store.predict(test) == graph.labels[test]).mean())
           if len(test) else 0.0)
    return {
        "inference_ms_per_node": round(run.stats["ms_per_node"], 5),
        "serve_p50_ms": round(st["p50_ms"], 4),
        "serve_p99_ms": round(st["p99_ms"], 4),
        "serve_qps": round(st["qps"], 1),
        "serve_acc": round(acc, 6),
        "serve_snapshot_version": int(st["snapshot_version"]),
        "serve_staleness_max_s": round(st["staleness_max_s"], 4),
        "serve_shed": int(st["n_shed"]),
        "serve_forced_refresh": int(st["n_forced_refresh"]),
    }


#: every paradigm name `make_source` dispatches on — the sampler axis of
#: the (b, β, sampler) cube `sweep(sources=...)` runs
PARADIGMS = ("fullgraph", "fullgraph_sharded", "minibatch",
             "minibatch_sharded", "cluster", "importance")


def make_source(paradigm: str, b: Optional[int] = None,
                fanouts: Optional[Sequence[int]] = None) -> BatchSource:
    """The one paradigm-name -> BatchSource mapping (shared by
    run_experiment and benchmarks/bench_engine.py)."""
    if paradigm == "fullgraph":
        return FullGraphSource()
    if paradigm == "fullgraph_sharded":
        return ShardedFullGraphSource()
    if paradigm == "minibatch":
        return SampledSource(batch_size=b, fanouts=fanouts)
    if paradigm == "minibatch_sharded":
        return ShardedSampledSource(batch_size=b, fanouts=fanouts)
    if paradigm == "cluster":
        return ClusterSource(batch_size=b)
    if paradigm == "importance":
        return ImportanceSampledSource(batch_size=b, fanouts=fanouts)
    raise ValueError(
        f"paradigm must be one of {PARADIGMS}, got {paradigm!r}")


def run_experiment(graph: Graph, cfg: GNNConfig, plan: TrainPlan,
                   paradigm: str = "minibatch",
                   b: Optional[int] = None,
                   fanouts: Optional[Sequence[int]] = None,
                   source: Optional[BatchSource] = None,
                   callbacks: Sequence[Callback] = (),
                   report_loss: Optional[float] = None,
                   report_acc: Optional[float] = None,
                   keep_result: bool = False,
                   inference: bool = False,
                   serve_queries: int = 64) -> Dict:
    """One grid point -> one structured row (spec + metrics).

    ``paradigm`` is "minibatch" or "fullgraph"; a custom ``source``
    overrides it.  ``report_loss`` / ``report_acc`` add iteration-to-*
    metrics WITHOUT stopping the run (the plan's ``target_loss`` /
    ``target_acc`` both stop and report).  With ``keep_result`` the full
    TrainResult (params + History) rides along under "_result" for
    callers that plot curves.  ``inference`` appends the serving-cost
    columns from ``inference_metrics`` (layer-wise embed ms/node, serve
    p50/p99/qps over ``serve_queries`` queries, cached-embedding test
    accuracy) so grid points are comparable by whole-pipeline cost.
    """
    # validate the EFFECTIVE (b, fanouts) the run will use, not just the
    # base cfg — bad overrides must fail fast, not deep in the sampler
    if b is not None or fanouts is not None:
        cfg = dataclasses.replace(
            cfg,
            batch_size=cfg.batch_size if b is None else b,
            fanout=cfg.fanout if fanouts is None else tuple(fanouts))
    cfg.validate()
    if source is None:
        source = make_source(paradigm, b=b, fanouts=fanouts)
    trainer = Trainer(graph, cfg, plan, source=source,
                      extra_callbacks=callbacks)
    try:
        res = trainer.run()
    finally:
        trainer.close()      # release device refs between grid points
    # label the row from the source that actually ran (bind() resolved
    # its b/fanouts), not from the `paradigm` string it may override
    name = getattr(source, "name", "custom")
    if name.startswith("fullgraph"):
        spec = {"paradigm": name, "b": len(graph.train_nodes),
                "fanouts": f"d_max={graph.d_max}"}
    elif name == "cluster":
        # fan-out does not apply: the batch structure is k-of-P clusters
        spec = {"paradigm": name, "b": getattr(source, "b", b),
                "fanouts": f"clusters(k={getattr(source, 'k', '?')}"
                           f"/P={getattr(source, 'n_parts_', '?')})"}
    else:
        spec = {"paradigm": name,
                "b": getattr(source, "b", b or cfg.batch_size),
                "fanouts": "x".join(map(str, getattr(source, "fanouts",
                                                     None) or fanouts
                                        or cfg.fanout))}
    row = {**spec, "seed": plan.seed, **metrics_row(
        res,
        plan.target_loss if report_loss is None else report_loss,
        plan.target_acc if report_acc is None else report_acc)}
    if inference:
        row.update(inference_metrics(graph, cfg, res.params,
                                     serve_queries=serve_queries,
                                     seed=plan.seed))
    if keep_result:
        row["_result"] = res
    return row


# ---------------------------------------------------------------------------
# (b, β) sweep — crash-safe via a JSONL completion journal
# ---------------------------------------------------------------------------

def _point_key(paradigm: str, b: Optional[int],
               fo: Optional[Tuple[int, ...]], seed: int) -> str:
    """Stable journal identity of one grid point."""
    fos = "x".join(map(str, fo)) if fo else "-"
    return f"{paradigm}|{b if b is not None else '-'}|{fos}|{seed}"


def _load_journal(path: Optional[str]) -> Dict[str, Dict]:
    """Completed rows keyed by point, from a previous (crashed) sweep.
    Only ``status == "ok"`` records count as done — error rows are
    RETRIED on resume.  A torn final line (crash mid-append) is skipped,
    not fatal: its point simply reruns."""
    done: Dict[str, Dict] = {}
    if not path or not os.path.exists(path):
        return done
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if rec.get("status") == "ok" and "key" in rec:
                done[rec["key"]] = rec.get("row", {})
    return done


def _append_journal(path: str, rec: Dict) -> None:
    """Durable append: one JSON line, flushed + fsynced before the sweep
    moves on, so a kill after this point cannot lose the record."""
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
        f.flush()
        os.fsync(f.fileno())


def _is_pallas_failure(e: BaseException) -> bool:
    """Does this look like the Pallas/Mosaic aggregation kernel failing
    to lower on this backend (as opposed to a training bug)?"""
    s = f"{type(e).__name__}: {e}"
    return any(m in s for m in ("Mosaic", "mosaic", "Pallas", "pallas",
                                "Triton", "triton"))


def sweep(graph: Graph, cfg: GNNConfig, plan: TrainPlan,
          batch_sizes: Sequence[int] = (),
          fanout_grid: Sequence[Sequence[int]] = (),
          include_fullgraph: bool = False,
          sources: Sequence[str] = ("minibatch",),
          seeds: Sequence[int] = (0,),
          verbose: bool = False,
          journal: Optional[str] = None,
          inference: bool = False,
          serve_queries: int = 64) -> List[Dict]:
    """Run the (b, β, sampler) product grid — the paper's §5 plane plus
    a sampler axis over the mini-batch families (``sources`` names from
    ``PARADIGMS``: minibatch, minibatch_sharded, cluster, importance;
    fullgraph / fullgraph_sharded collapse to one point each since
    neither b nor β applies at the (b=n, β=d_max) corner).

    ``fanout_grid`` entries are per-hop fan-out tuples (int entries are
    broadcast to all ``cfg.n_layers`` hops).  Each grid point gets a cfg
    copy with that (b, β) so ``GNNConfig.validate()`` rejects bad grids
    before any sampling or kernel work starts.

    ``journal`` makes the sweep CRASH-SAFE (docs/training_api.md "Fault
    tolerance"): every completed point is appended to the JSONL file
    (flushed + fsynced) before the next one starts, rerunning with the
    same path skips points already recorded ``ok`` (their journaled rows
    are returned in grid order), and a per-point failure becomes an
    ``status="error"`` row instead of killing the remaining grid
    (error points are retried on resume).  ``inference`` appends the
    serving-cost columns (``inference_metrics``) to every row, making
    the cube a (b, β, sampler, serving-cost) comparison — the paper
    extension.  Independently of the journal,
    a point whose Pallas aggregation kernel fails to lower is retried
    once with ``use_agg_kernel=False`` (loud RuntimeWarning; the row
    carries ``agg_kernel_degraded=True``) so one backend quirk does not
    sink a long sweep.
    """
    points: List[Tuple[str, Optional[int], Optional[Tuple[int, ...]]]] = []
    seen = set()
    if include_fullgraph:
        points.append(("fullgraph", None, None))
        seen.add("fullgraph")      # sources=("fullgraph", ...) dedups too
    for b, beta, src in itertools.product(batch_sizes, fanout_grid,
                                          sources):
        fo = (tuple(beta) if isinstance(beta, (tuple, list))
              else (int(beta),) * cfg.n_layers)
        if src.startswith("fullgraph"):
            # neither b nor β applies at the (b=n, β=d_max) corner:
            # crossing the grid axes would just rerun one identical
            # point per (b, β) cell — keep exactly one per source
            if src in seen:
                continue
            seen.add(src)
            points.append((src, None, None))
            continue
        if src == "cluster":
            # fan-out does not apply to cluster batches: crossing the β
            # axis would just rerun identical, identically-labelled
            # grid points — keep one per (source, b)
            if (src, int(b)) in seen:
                continue
            seen.add((src, int(b)))
        points.append((src, int(b), fo))
    done = _load_journal(journal)
    rows: List[Dict] = []
    for paradigm, b, fo in points:
        for seed in seeds:
            key = _point_key(paradigm, b, fo, seed)
            if key in done:
                rows.append(done[key])
                if verbose:
                    print(f"journal: skipping completed point {key}",
                          flush=True)
                continue
            plan_pt = dataclasses.replace(plan, seed=seed)
            if plan.ckpt_every:
                # namespace checkpoints per grid point/seed so runs don't
                # overwrite each other's ckpt_{step}.npz files
                tag = (paradigm if paradigm.startswith("fullgraph")
                       else f"b{b}_f{'x'.join(map(str, fo))}"
                       if paradigm == "minibatch"
                       else f"{paradigm}_b{b}_f{'x'.join(map(str, fo))}")
                plan_pt = dataclasses.replace(
                    plan_pt, ckpt_dir=os.path.join(plan.ckpt_dir,
                                                   f"{tag}_s{seed}"))
            # run_experiment owns the effective-(b, fanouts) validation
            # and fails fast on bad grid points (satellite)
            try:
                try:
                    row = run_experiment(graph, cfg, plan_pt,
                                         paradigm=paradigm, b=b,
                                         fanouts=fo, inference=inference,
                                         serve_queries=serve_queries)
                # Mosaic/Triton lowering failures surface as
                # RuntimeError (XlaRuntimeError), NotImplementedError,
                # or ValueError/TypeError from the pallas lowering
                # rules — anything else is a training bug and must not
                # enter the degrade path at all
                except (RuntimeError, NotImplementedError, ValueError,
                        TypeError) as e:
                    if not (cfg.use_agg_kernel and _is_pallas_failure(e)):
                        raise
                    warnings.warn(
                        f"Pallas aggregation kernel failed to lower for "
                        f"point {key} ({type(e).__name__}: {e}) — "
                        f"DEGRADING to the einsum path for this point "
                        f"(use_agg_kernel=False); throughput rows from "
                        f"it are NOT kernel-path numbers",
                        RuntimeWarning, stacklevel=2)
                    row = run_experiment(
                        graph,
                        dataclasses.replace(cfg, use_agg_kernel=False),
                        plan_pt, paradigm=paradigm, b=b, fanouts=fo,
                        inference=inference, serve_queries=serve_queries)
                    row["agg_kernel_degraded"] = True
            except Exception as e:
                # deliberately broad: without a journal this sweep is
                # interactive — fail fast.  With one it is a long
                # unattended grid: isolate ANY per-point failure,
                # record it, keep going (retried on resume).  Injected
                # faults (core.faults) derive from BaseException
                # precisely so they still crash through this recovery.
                if journal is None:
                    raise
                row = {"paradigm": paradigm, "b": b,
                       "fanouts": "x".join(map(str, fo)) if fo else "",
                       "seed": seed, "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
                _append_journal(journal, {"key": key, "status": "error",
                                          "error": row["error"]})
                rows.append(row)
                if verbose:
                    print(f"point {key} FAILED: {row['error']}",
                          flush=True)
                continue
            if journal is not None:
                _append_journal(journal, {
                    "key": key, "status": "ok",
                    "row": {k: v for k, v in row.items()
                            if not k.startswith("_")}})
                done[key] = row
            rows.append(row)
            if verbose:
                print(",".join(f"{k}={v}" for k, v in row.items()
                               if not k.startswith("_")), flush=True)
            # chaos-test crash site: a kill here (point finished AND
            # journaled) must lose no work on resume
            faults.maybe_crash("sweep.after_point")
    return rows


def save_rows(name: str, rows: List[Dict], out_dir: str = OUT_DIR
              ) -> Dict[str, str]:
    """Structured outputs: <name>.json (row list) + <name>.csv."""
    os.makedirs(out_dir, exist_ok=True)
    rows = [{k: v for k, v in r.items() if not k.startswith("_")}
            for r in rows]
    jpath = os.path.join(out_dir, f"{name}.json")
    with open(jpath, "w") as f:
        json.dump(rows, f, indent=1)
    cpath = os.path.join(out_dir, f"{name}.csv")
    keys: List[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(cpath, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys, restval="")
        w.writeheader()
        w.writerows(rows)
    return {"json": jpath, "csv": cpath}


# ---------------------------------------------------------------------------
# CLI — tiny sweep smoke for CI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> List[Dict]:
    from repro.data import make_preset

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="arxiv-like")
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--iters", type=int, default=4)
    ap.add_argument("--bs", type=int, nargs="+", default=[32, 64])
    ap.add_argument("--fanout", type=int, nargs="+", default=[3])
    ap.add_argument("--sources", nargs="+", default=["minibatch"],
                    help="sampler axis of the grid (see PARADIGMS): "
                         "minibatch, minibatch_sharded, cluster, "
                         "importance, fullgraph_sharded")
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--fullgraph", action="store_true")
    ap.add_argument("--kernel", action="store_true",
                    help="run every grid point through the Pallas "
                         "aggregation kernel (interpret mode — works on "
                         "CPU and on multi-device meshes via shard_map)")
    ap.add_argument("--feats-layout", default="replicated",
                    choices=["replicated", "sharded"],
                    help="gather-source table layout for the kernel "
                         "paths: 'sharded' rows the feature table over "
                         "the NODES mesh axis with a degree-ordered hot "
                         "cache (full-graph) / host LRU accounting "
                         "(sampled) — pair with --kernel and a "
                         "multi-device mesh")
    ap.add_argument("--cache-rows", type=int, default=-1,
                    help="hot-cache size C for --feats-layout sharded "
                         "(-1 auto = n//8, 0 off)")
    ap.add_argument("--journal", default=None,
                    help="JSONL completion journal: crash-safe sweeps "
                         "— rerunning with the same path skips points "
                         "already recorded ok")
    ap.add_argument("--inference", action="store_true",
                    help="append the serving-cost columns to every row "
                         "(layer-wise embed ms/node, serve p50/p99/qps, "
                         "cached-embedding test accuracy)")
    ap.add_argument("--serve-queries", type=int, default=32)
    ap.add_argument("--out", default="sweep_smoke")
    args = ap.parse_args(argv)

    graph = make_preset(args.preset, n=args.n, seed=0)
    cfg = GNNConfig(name="sweep", model="graphsage", n_nodes=graph.n,
                    feat_dim=graph.feats.shape[1], hidden=32,
                    n_classes=graph.n_classes, n_layers=args.layers,
                    fanout=(5,) * args.layers, batch_size=64, loss="ce",
                    use_agg_kernel=args.kernel, agg_interpret=True,
                    feats_layout=args.feats_layout,
                    feat_cache_rows=args.cache_rows)
    plan = TrainPlan(lr=args.lr, n_iters=args.iters,
                     eval_every=args.eval_every)
    fo = (tuple(args.fanout) * args.layers if len(args.fanout) == 1
          else tuple(args.fanout))
    rows = sweep(graph, cfg, plan, batch_sizes=args.bs, fanout_grid=[fo],
                 include_fullgraph=args.fullgraph, sources=args.sources,
                 verbose=True, journal=args.journal,
                 inference=args.inference,
                 serve_queries=args.serve_queries)
    paths = save_rows(args.out, rows)
    print(json.dumps({"rows": len(rows), **paths}))
    return rows


if __name__ == "__main__":
    main()
