"""GCN / GraphSAGE(mean) / GAT — the paper's three models (§5), each with
a full-graph (ELL) and a mini-batch (fan-out tree) forward path sharing
the same parameters.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def layer_dims(cfg: GNNConfig, feat_dim: int) -> List[tuple]:
    dims = []
    d_in = feat_dim
    for l in range(cfg.n_layers):
        d_out = cfg.n_classes if l == cfg.n_layers - 1 else cfg.hidden
        dims.append((d_in, d_out))
        d_in = d_out
    return dims


def init_gnn(key, cfg: GNNConfig, feat_dim: int) -> List[Dict[str, Any]]:
    params = []
    for li, (d_in, d_out) in enumerate(layer_dims(cfg, feat_dim)):
        k = jax.random.fold_in(key, li)
        sc = 1.0 / math.sqrt(d_in)
        if cfg.model == "gcn":
            p = {"w": sc * jax.random.normal(k, (d_in, d_out), F32)}
        elif cfg.model == "graphsage":
            k1, k2 = jax.random.split(k)
            p = {"w_self": sc * jax.random.normal(k1, (d_in, d_out), F32),
                 "w_neigh": sc * jax.random.normal(k2, (d_in, d_out), F32)}
        else:  # gat
            h = cfg.gat_heads
            last = li == cfg.n_layers - 1
            # hidden layers concat heads (dh = d_out/h); the last layer
            # emits full class logits per head and averages them.
            dh = d_out if last else max(d_out // h, 1)
            k1, k2, k3 = jax.random.split(k, 3)
            p = {"w": sc * jax.random.normal(k1, (d_in, h, dh), F32),
                 "a_src": 0.1 * jax.random.normal(k2, (h, dh), F32),
                 "a_dst": 0.1 * jax.random.normal(k3, (h, dh), F32)}
        params.append(p)
    return params


# ---------------------------------------------------------------------------
# layer primitives (shared by both paths)
# ---------------------------------------------------------------------------

def _kernel_agg(cfg: GNNConfig, table, idx, w, self_rows=None,
                w_self=None, mesh=None):
    """Σ_k w[b,k] · table[idx[b,k]] (+ fused w_self[b] · self_rows[b]
    epilogue) via the batch-tiled, double-buffered Pallas kernel.  With
    ``mesh`` the kernel runs shard-locally over the NODES axis
    (shard_map: rows sharded, table replicated, dfeats psum'd in the
    VJP); without it, single-device dispatch."""
    if mesh is not None:
        from repro.kernels.neighbor_agg.ops import neighbor_agg_sharded
        return neighbor_agg_sharded(
            table, idx, w, self_rows, w_self, mesh=mesh,
            interpret=cfg.agg_interpret, b_tile=cfg.agg_b_tile,
            d_tile=cfg.agg_d_tile, k_slab=cfg.agg_k_slab)
    from repro.kernels.neighbor_agg.ops import neighbor_agg
    return neighbor_agg(table, idx, w, self_rows, w_self,
                        use_kernel=True, kernel="tiled",
                        interpret=cfg.agg_interpret, b_tile=cfg.agg_b_tile,
                        d_tile=cfg.agg_d_tile, k_slab=cfg.agg_k_slab)


def _wsum(cfg: GNNConfig, w_edge, h_nb, h_self=None, w_self=None,
          mesh=None):
    """Weighted neighbor sum over ALREADY-GATHERED features:
    out[..., :] = Σ_k w_edge[..., k] * h_nb[..., k, :]
                  [+ w_self[...] * h_self[..., :]].

    With cfg.use_agg_kernel the fan-out tree is flattened to a [B*K, d]
    table + identity ids so the mini-batch path exercises the same tiled
    kernel (zero-weight padding edges stay exact); the optional self
    term rides the kernel's fused accumulator-init epilogue instead of
    a separate output-sized elementwise pass.  With ``mesh`` the
    flattened rows run shard-locally over the NODES axis (the table is
    derived from the row-sharded tree level, so no collective is
    needed)."""
    fused = h_self is not None
    if not cfg.use_agg_kernel:
        out = jnp.einsum("...k,...kd->...d", w_edge, h_nb)
        return out + w_self[..., None] * h_self if fused else out
    k, d = h_nb.shape[-2], h_nb.shape[-1]
    lead = h_nb.shape[:-2]
    b = h_nb.reshape(-1, d).shape[0] // k
    if mesh is not None:
        from repro.kernels.neighbor_agg.ops import neighbor_agg_batch_sharded
        out = neighbor_agg_batch_sharded(
            w_edge.reshape(b, k), h_nb.reshape(b, k, d),
            h_self.reshape(b, d) if fused else None,
            w_self.reshape(b) if fused else None, mesh=mesh,
            interpret=cfg.agg_interpret, b_tile=cfg.agg_b_tile,
            d_tile=cfg.agg_d_tile, k_slab=cfg.agg_k_slab)
        return out.reshape(lead + (d,))
    table = h_nb.reshape(-1, d)
    idx = jnp.arange(b * k, dtype=jnp.int32).reshape(b, k)
    out = _kernel_agg(cfg, table, idx, w_edge.reshape(b, k),
                      self_rows=h_self.reshape(b, d) if fused else None,
                      w_self=w_self.reshape(b) if fused else None)
    return out.reshape(lead + (d,))


def _gcn_layer(cfg, p, h_self, h_nb, w_edge, w_self, mesh=None):
    """h_self [..., d]; h_nb [..., K, d]; w_edge [..., K]; w_self [...]."""
    return _wsum(cfg, w_edge, h_nb, h_self, w_self, mesh=mesh) @ p["w"]


def _sage_layer(cfg, p, h_self, h_nb, mask, mesh=None):
    cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
    mean = _wsum(cfg, mask, h_nb, mesh=mesh) / cnt
    return h_self @ p["w_self"] + mean @ p["w_neigh"]


def _gat_layer(p, h_self, h_nb, mask):
    z_s = jnp.einsum("...d,dhe->...he", h_self, p["w"])        # [..., H, dh]
    z_n = jnp.einsum("...kd,dhe->...khe", h_nb, p["w"])        # [..., K, H, dh]
    e_s = jnp.einsum("...he,he->...h", z_s, p["a_src"])        # [..., H]
    e_n = jnp.einsum("...khe,he->...kh", z_n, p["a_dst"])      # [..., K, H]
    e = jax.nn.leaky_relu(e_s[..., None, :] + e_n, 0.2)
    e = jnp.where(mask[..., None], e, -1e30)
    # self edge always valid
    e_self = jax.nn.leaky_relu(e_s + jnp.einsum("...he,he->...h", z_s,
                                                p["a_dst"]))[..., None, :]
    ea = jnp.concatenate([e, e_self], axis=-2)                 # [...,K+1,H]
    alpha = jax.nn.softmax(ea, axis=-2)
    zn_all = jnp.concatenate([z_n, z_s[..., None, :, :]], axis=-3)
    out = jnp.einsum("...kh,...khe->...he", alpha, zn_all)
    return out.reshape(out.shape[:-2] + (-1,))                 # concat heads


def _apply_layer(cfg: GNNConfig, p, h_self, h_nb, mask, w_edge, w_self,
                 last: bool, mesh=None):
    if cfg.model == "gcn":
        out = _gcn_layer(cfg, p, h_self, h_nb, w_edge, w_self, mesh=mesh)
    elif cfg.model == "graphsage":
        out = _sage_layer(cfg, p, h_self, h_nb, mask, mesh=mesh)
    else:
        out = _gat_layer(p, h_self, h_nb, mask)
        if last:  # average heads into class logits
            h = cfg.gat_heads
            out = out.reshape(out.shape[:-1] + (h, -1)).mean(-2)
    return out if last else jax.nn.relu(out)


# ---------------------------------------------------------------------------
# full-graph forward (ELL)
# ---------------------------------------------------------------------------

def full_graph_forward(params, cfg: GNNConfig, feats, ell_idx, ell_w,
                       w_self, mesh=None, feats_plan=None,
                       return_layers=False):
    """feats [n, r]; ell_idx/ell_w [n, K]; w_self [n] -> logits [n, C].

    Distributed-execution shape (§Perf H1, measured in EXPERIMENTS.md):
      * the gather SOURCE is explicitly replicated across the mesh before
        jnp.take — one all-gather of [n, d] instead of GSPMD's
        all-reduce of the [n, K, d] gather output (K x the wire bytes);
      * when a layer shrinks its width (d_out < d_in), the linear
        transform runs BEFORE aggregation (Ã(hW) == (Ãh)W for GCN and
        the GraphSAGE neighbor branch) so the gather moves d_out-wide
        rows;
      * aggregation traffic runs in cfg.dtype (bf16 at production scale).
    All three are exact (up to float associativity).

    With cfg.use_agg_kernel the gcn/graphsage Ã-aggregation runs through
    the batch-tiled Pallas software-gather kernel on the replicated
    source table — no [n, K, d] gather is materialized (the kernel DMAs
    rows tile-by-tile and keeps the (b_tile, d_tile) accumulator in
    VMEM).  GAT keeps the einsum path (per-edge softmax attention).

    ``mesh`` (sharded sources) partitions the KERNEL path over the
    NODES mesh axis via shard_map — ELL rows shard, the source table
    replicates, and the VJP psum-reduces the table gradient; the einsum
    path ignores it (GSPMD partitions that one by itself).

    ``feats_plan`` (a ``FeatShardPlan``, built per bind by the sharded
    sources under ``cfg.feats_layout == "sharded"``) switches the
    gcn/graphsage kernel path to ``neighbor_agg_featshard``: the source
    table is constrained NODES-row-sharded instead of replicated — no
    device ever holds the full [n, d] table — with the plan's
    degree-ordered hot cache splitting the gather into shard-local hits
    and one compacted cold-miss all_gather.  Every layer's output table
    stays NODES-sharded, so it feeds the next layer (and the layer-wise
    inference pass) without a relayout.  GAT ignores the plan (its
    attention gather is not a weighted sum; engine binds never build a
    plan for it).

    ``return_layers`` additionally returns every layer's POST-activation
    table ``[h_1, ..., h_L]`` (``h_L`` = the logits) — the per-layer
    oracle ``core.inference`` validates its layer-wise path against.
    The default path is untouched (the flag only appends to a Python
    list), so the pre-existing golden loss sequences stay bit-for-bit.
    """
    from repro import sharding as sh

    h = feats
    maskb = ell_w > 0
    mask = maskb.astype(h.dtype)
    agg_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else h.dtype
    # aggregation consumes the mask in agg_dt: cast the bool ONCE
    # instead of round-tripping the f32 mask (bool->f32->bf16 was a
    # second full [n, K] pass per layer under dtype="bfloat16")
    mask_agg = mask if agg_dt == h.dtype else maskb.astype(agg_dt)
    n_layers = len(params)
    fs_active = (feats_plan is not None and cfg.use_agg_kernel
                 and cfg.model in ("gcn", "graphsage"))
    tab_axes = (sh.NODES, None) if fs_active else (None, None)

    def replicate(src):
        """Cast + constrain the per-layer gather source ONCE; every
        consumer (aggregation, gather, fused self branch) shares the
        result, so each layer emits a single table constraint.  Under a
        feats_plan the "replicated" name is historical: the constraint
        is NODES-row-sharded and no full copy exists anywhere."""
        return sh.constrain(src.astype(agg_dt), tab_axes)

    def agg_w(srcr, w_edge):
        """Σ_k w_edge[n,k] · srcr[ell_idx[n,k]] without the [n,K,d]
        blowup; ``srcr`` is the already cast+constrained table."""
        if fs_active:
            from repro.kernels.neighbor_agg.ops import neighbor_agg_featshard
            return neighbor_agg_featshard(
                srcr, w_edge.astype(agg_dt), feats_plan,
                interpret=cfg.agg_interpret, b_tile=cfg.agg_b_tile,
                d_tile=cfg.agg_d_tile,
                k_slab=cfg.agg_k_slab).astype(h.dtype)
        if cfg.use_agg_kernel:
            return _kernel_agg(cfg, srcr, ell_idx,
                               w_edge.astype(agg_dt),
                               mesh=mesh).astype(h.dtype)
        return jnp.einsum("nk,nkd->nd", w_edge.astype(agg_dt),
                          jnp.take(srcr, ell_idx, axis=0)).astype(h.dtype)

    layers = []
    for li, p in enumerate(params):
        last = li == n_layers - 1
        if cfg.model == "gcn":
            w = p["w"]
            pre = w.shape[1] < h.shape[1]
            src = (h @ w) if pre else h
            srcr = replicate(src)
            if cfg.use_agg_kernel:
                # fused epilogue: the self row IS the source table row b,
                # so the kernel consumes the same constrained table twice
                if fs_active:
                    from repro.kernels.neighbor_agg.ops import \
                        neighbor_agg_featshard
                    agg = neighbor_agg_featshard(
                        srcr, ell_w.astype(agg_dt), feats_plan,
                        self_rows=srcr, w_self=w_self.astype(agg_dt),
                        interpret=cfg.agg_interpret, b_tile=cfg.agg_b_tile,
                        d_tile=cfg.agg_d_tile,
                        k_slab=cfg.agg_k_slab).astype(h.dtype)
                else:
                    agg = _kernel_agg(cfg, srcr, ell_idx,
                                      ell_w.astype(agg_dt), self_rows=srcr,
                                      w_self=w_self.astype(agg_dt),
                                      mesh=mesh).astype(h.dtype)
            else:
                # the self branch rides the SAME cast table as agg_w
                # (one constraint per layer, matching the fused kernel's
                # operand plumbing)
                agg = agg_w(srcr, ell_w) + (w_self.astype(agg_dt)[:, None]
                                            * srcr).astype(h.dtype)
            out = agg if pre else agg @ w
        elif cfg.model == "graphsage":
            wn = p["w_neigh"]
            pre = wn.shape[1] < h.shape[1]
            src = (h @ wn) if pre else h
            cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
            mean = agg_w(replicate(src), mask_agg) / cnt
            out = h @ p["w_self"] + (mean if pre else mean @ wn)
        else:  # gat — gathers the (usually narrower) projected z already
            nb = jnp.take(replicate(h), ell_idx, axis=0).astype(h.dtype)
            out = _gat_layer(p, h, nb, maskb)
            if last:
                heads = cfg.gat_heads
                out = out.reshape(out.shape[:-1] + (heads, -1)).mean(-2)
        h = out if last else jax.nn.relu(out)
        if return_layers:
            layers.append(h)
    return (h, layers) if return_layers else h


# ---------------------------------------------------------------------------
# mini-batch forward (fan-out tree)
# ---------------------------------------------------------------------------

def minibatch_forward(params, cfg: GNNConfig, hop_feats: Sequence,
                      masks: Sequence, weights: Sequence, self_w: Sequence,
                      mesh=None):
    """hop_feats[d]: [b, f1..fd, r]; masks/weights[d]: [b, f1..f(d+1)].
    Layer l aggregates hop d+1 into hop d for d < L - l.  ``mesh``
    (sharded sources) runs the kernel path shard-locally over the
    NODES-sharded target axis; the einsum path ignores it."""
    hs = list(hop_feats)
    n_layers = len(params)
    for li, p in enumerate(params):
        last = li == n_layers - 1
        new_hs = []
        for d in range(len(hs) - 1):
            new_hs.append(_apply_layer(
                cfg, p, hs[d], hs[d + 1],
                masks[d].astype(hs[d].dtype), weights[d], self_w[d], last,
                mesh=mesh))
        hs = new_hs
    assert len(hs) == 1
    return hs[0]                                      # [b, C]


# ---------------------------------------------------------------------------
# losses (paper: CE and MSE, §3)
# ---------------------------------------------------------------------------

def gnn_loss(logits, labels, kind: str, n_classes: int, valid=None,
             weight=None):
    """CE / MSE over target rows.  ``valid`` (float 0/1 per row, or
    None) masks padded rows out of the mean: padded rows contribute
    exact zeros and the divisor is the valid count, so the result
    matches the unpadded mean up to float summation order.  ``weight``
    (float per row, or None) scales each row's loss BEFORE the mean and
    does NOT enter the divisor — importance-sampled batches pass
    w_j = 1/(n·p_j) so the weighted batch mean stays an unbiased
    estimator of the full training objective regardless of whether the
    sampling scores were normalized."""
    if kind == "mse":
        onehot = jax.nn.one_hot(labels, n_classes, dtype=F32)
        rows = jnp.sum(jnp.square(logits.astype(F32) - onehot), axis=-1)
        if weight is not None:
            rows = rows * weight
        if valid is None:
            return 0.5 * jnp.mean(rows)
        return 0.5 * (jnp.sum(rows * valid) / jnp.sum(valid))
    logz = jax.scipy.special.logsumexp(logits.astype(F32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(F32), labels[..., None],
                             axis=-1)[..., 0]
    rows = logz - ll
    if weight is not None:
        rows = rows * weight
    if valid is None:
        return jnp.mean(rows)
    return jnp.sum(rows * valid) / jnp.sum(valid)


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(F32))
