"""Logical sharding axes -> mesh PartitionSpecs.

Params/activations are annotated with *logical* axis names; they resolve
against whatever mesh is active ("data","model") or ("pod","data","model").
The batch logical axis spans ("pod","data") on a multi-pod mesh so the global
batch shards over every chip.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

BATCH = "batch"    # data-parallel axis (pod x data)
MODEL = "model"    # tensor-parallel axis
NODES = "nodes"    # GNN node-parallel axis (alias of batch axes)

# Production tensor-parallel degree (the "model" axis of both meshes).
# Head / expert / vocab dims are padded or replicated based on divisibility
# against this constant; smoke-test meshes use model=1, which any dim divides.
MODEL_PAR = 16


def pad_to(n: int, m: int = MODEL_PAR) -> int:
    return ((n + m - 1) // m) * m


def shard_heads(n: int) -> bool:
    """Shard a heads-like dim over `model` only when it stays divisible."""
    return n % MODEL_PAR == 0


def padded_heads(n: int) -> int:
    """Query heads are padded up to a MODEL_PAR multiple when big enough to
    shard (llama4: 40 -> 48); small head counts (smoke configs) stay as-is
    and replicate."""
    if n % MODEL_PAR == 0 or n < MODEL_PAR:
        return n
    return pad_to(n)


ALL = "all"        # every mesh axis (for unshardable-batch decode caches)
FSDP = "fsdp"      # weight sharding over the data axis (ZeRO-3 style).
#                    NOT over "pod": cross-pod traffic stays gradient-only.


def axis_map(mesh: Mesh) -> dict:
    names = mesh.axis_names
    if "pod" in names:
        batch_axes: Any = ("pod", "data")
        all_axes: Any = ("pod", "data", "model")
    else:
        batch_axes = "data"
        all_axes = ("data", "model")
    return {BATCH: batch_axes, NODES: batch_axes, MODEL: "model",
            ALL: all_axes, FSDP: "data"}


def resolve(logical: Sequence[Optional[str]], mesh: Mesh) -> P:
    m = axis_map(mesh)
    return P(*[m.get(ax) if ax is not None else None for ax in logical])


def named(logical: Sequence[Optional[str]], mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, resolve(logical, mesh))


def tree_named(spec_tree: Any, mesh: Mesh) -> Any:
    """Map a pytree of logical-spec tuples to NamedShardings."""
    return jax.tree.map(
        lambda s: named(s, mesh),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(e is None or isinstance(e, str) for e in x),
    )


# --- active mesh for intra-jit sharding constraints ------------------------
# get_abstract_mesh() is empty inside jit traces in this jax version, so the
# launcher/dry-run explicitly activates the mesh around tracing.
_ACTIVE_MESH: Optional[Mesh] = None


class activate(object):
    """Context manager: `with sharding.activate(mesh): jit(...).lower(...)`
    Makes sh.constrain() resolve logical axes during tracing (also enters
    the legacy `with mesh:` context so bare-PartitionSpec constraints bind).
    """

    def __init__(self, mesh: Mesh):
        self.mesh = mesh

    def __enter__(self):
        global _ACTIVE_MESH
        self._prev = _ACTIVE_MESH
        _ACTIVE_MESH = self.mesh
        self._ctx = self.mesh
        self._ctx.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        global _ACTIVE_MESH
        _ACTIVE_MESH = self._prev
        return self._ctx.__exit__(*exc)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def batch_mesh_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


@functools.lru_cache(maxsize=None)
def _node_mesh_cached(n_devices: int) -> Mesh:
    return Mesh(np.asarray(jax.devices()[:n_devices]), ("data",))


def node_mesh(n_devices: Optional[int] = None) -> Mesh:
    """One-axis ("data",) mesh over the local devices — the NODES
    logical axis resolves onto it, so a NODES-sharded array lays its
    rows out data-parallel over every local device (GNN full-graph
    training; see engine.ShardedFullGraphSource).

    Memoized per device count: repeated binds (every sweep grid point
    re-binds its source) must hand back the SAME Mesh object, so step
    caches keyed on the closed-over constants' identity keep hitting."""
    return _node_mesh_cached(len(jax.devices()) if n_devices is None
                             else n_devices)


def row_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """NODES-sharded leading axis, replicated on the rest — the layout
    shared by ShardedFullGraphSource's ELL rows and
    ShardedSampledSource's per-batch target axis."""
    return named((NODES,) + (None,) * (ndim - 1), mesh)


# --- NODES-partitioned kernels (shard_map) ---------------------------------

def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-compat shard_map (``jax.shard_map``/``check_vma`` on new
    jax, ``jax.experimental.shard_map``/``check_rep`` on 0.4.x) with
    replication checking OFF: the neighbor-agg kernels place their psum
    explicitly in the custom VJP (see kernels/README.md "Sharding")."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def nodes_axis(mesh: Mesh):
    """The mesh axis name(s) the NODES logical axis resolves onto
    ("data", or ("pod", "data") on a multi-pod mesh)."""
    return axis_map(mesh)[NODES]


def nodes_shards(mesh: Mesh) -> int:
    """Number of shards along the NODES logical axis."""
    ax = nodes_axis(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ax = (ax,) if isinstance(ax, str) else ax
    return int(np.prod([sizes[a] for a in ax]))


def ell_agg_specs(mesh: Mesh, fused: bool) -> Tuple[Tuple[P, ...], P]:
    """(in_specs, out_spec) for the NODES-partitioned neighbor
    aggregation: output rows / ``idx`` / ``w`` (+ ``self_rows`` /
    ``w_self`` when fused) shard their leading axis over NODES, the
    feature table replicates — the per-shard gather is then purely
    local and only the VJP's dfeats needs a cross-shard psum."""
    ax = nodes_axis(mesh)
    row2, row1, repl = P(ax, None), P(ax), P(None, None)
    ins = (repl, row2, row2) + ((row2, row1) if fused else ())
    return ins, row2


def row_owner(n_pad: int, mesh: Mesh) -> np.ndarray:
    """Host-side owner map for an [n_pad, ...] NODES-row-sharded table:
    ``owner[i]`` is the NODES shard holding row ``i`` (jax lays a
    row-sharded array out as contiguous blocks of ``n_pad / shards``
    rows, which is exactly what the featshard plan classifies against;
    see kernels/neighbor_agg/featshard.py)."""
    n_sh = nodes_shards(mesh)
    if n_pad % n_sh:
        raise ValueError(
            f"row_owner: n_pad={n_pad} rows must divide the {n_sh} NODES "
            f"shards (pad first)")
    return (np.arange(n_pad) // (n_pad // n_sh)).astype(np.int32)


def feats_spec(mesh: Mesh, layout: str = "replicated") -> P:
    """PartitionSpec of the gather-source feature table under a
    ``GNNConfig.feats_layout``: ``"replicated"`` is the PR-5 sharded
    kernel's layout (every shard holds the full [n, d] table),
    ``"sharded"`` rows the table over NODES — P("nodes"->mesh axes, None)
    — for the out-of-core featshard path."""
    if layout == "sharded":
        return P(nodes_axis(mesh), None)
    if layout != "replicated":
        raise ValueError(f"unknown feats_layout: {layout!r}")
    return P(None, None)


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint against the activated mesh; no-op when no
    mesh is active (smoke tests) or when the spec can't bind to the
    active mesh."""
    if _ACTIVE_MESH is None:
        return x
    try:
        spec = resolve(logical, _ACTIVE_MESH)
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        # jax 0.4.x raises ValueError when the resolved spec names a mesh
        # axis the active mesh doesn't have (smoke meshes without a
        # "model" axis) or when the spec's rank disagrees with the array;
        # jax >= 0.5 surfaces sharding/axis-type mismatches from the new
        # mesh machinery as TypeError.  Anything else (tracer leaks,
        # internal errors) should propagate, not be eaten.
        return x
