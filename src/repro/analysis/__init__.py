"""repro.analysis — static audit pass over the repo's three hazard
surfaces (ISSUE 9):

* :mod:`repro.analysis.jaxpr_audit` — trace the engine-bound
  step/eval/inference functions for every committed sweep variant and
  walk the jaxprs for dtype widenings, convert churn, host-constant
  capture, stray collectives, donation feasibility, and retrace
  stability.
* :mod:`repro.analysis.pallas_audit` — VMEM budgets from block/scratch
  shapes, DMA/semaphore pairing on every control path of the two-slot
  K-slab rotation, and bounds checks on scalar-prefetched indices.
* :mod:`repro.analysis.thread_audit` — AST concurrency lint over the
  thread-crossing modules (prefetch/engine/serving/featcache/
  inference): shared attributes written from two thread sides without
  lock/queue/ring discipline.

Run it via ``scripts/analyze.py`` / ``make analyze`` (CI-gated); the
intentional exceptions live in ``src/repro/analysis/allowlist.toml``.
"""
from .findings import (GATING, Finding, apply_allowlist, as_json, gating,
                       load_allowlist, render_report)

__all__ = [
    "Finding", "GATING", "apply_allowlist", "as_json", "gating",
    "load_allowlist", "render_report",
]
