"""Jaxpr auditor: trace the REAL engine-bound step/eval/inference
functions for every committed sweep variant and walk the jaxprs for
hazard classes the bench suite cannot see.

The variants reuse the engine's own plumbing — sources are constructed
and ``bind``-ed exactly like ``experiment.make_source`` does (minus
worker threads: sampled sources run with ``prefetch=False,
reuse_buffers=False`` and the cluster batch is drawn through
``_sample_union`` directly), and the step comes out of
``engine._cached_step`` with the source's own ``loss_consts()``, so the
audited jaxpr IS the jaxpr a sweep compiles, not a lookalike.

Hazard classes (ISSUE 9):

* **f64 widening** — any equation producing a float64/complex64+
  output.  The repo is an f32/bf16 codebase; a float64 aval means a
  host constant or ``enable_x64`` leak doubled the hot path's bytes.
* **convert churn** — ``convert_element_type`` applied directly to the
  output of another ``convert_element_type``: a round-trip (A->B->A)
  is a wasted pass over the array (warning); other double-converts
  collapse to one and are reported as info.
* **host-constant capture** — ``np.ndarray`` constants above a size
  threshold folded into the jaxpr.  Host arrays bake into the HLO as
  literals AND miss every identity-keyed trace cache, so a captured
  feature table is simultaneously an HBM and a retrace hazard.
  (Device ``jax.Array`` consts are the engine's deliberate design —
  ``_cached_step`` closes over the memoized ELL upload — and are
  tallied in the per-variant record, not flagged.)
* **collectives outside shard_map** — psum/all_gather/... equations
  not nested under a ``shard_map`` body run under GSPMD semantics
  where they are almost always a tracing bug in this codebase.
* **donation feasibility** — donated params/opt_state leaves whose
  (shape, dtype) cannot alias any step output would silently disable
  buffer reuse (error); donated batch leaves are donated for early
  deallocation only and are tallied, not flagged.
* **retrace stability** — a fresh source instance bound to the same
  graph must (a) hit ``_cached_step``'s identity-keyed cache (same
  function object back) and (b) retrace to a byte-identical canonical
  jaxpr.  Either failing means a ``sweep()`` recompiles per grid
  point and every bench number downstream is measuring the compiler.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from .findings import Finding

#: collective primitives that must only appear under shard_map
COLLECTIVES = frozenset({
    "psum", "psum2", "all_gather", "all_to_all", "ppermute", "pbroadcast",
    "psum_scatter", "reduce_scatter", "pmin", "pmax", "pgather",
})

#: primitives that introduce a shard_map scope for everything below
_SPMD_SCOPES = frozenset({"shard_map"})

#: host (np.ndarray) constants this large baked into a jaxpr are an
#: HLO-literal + retrace hazard; device consts are the engine's design
HOST_CONST_BYTES = 4096

F64 = frozenset({"float64", "complex128"})


# ---------------------------------------------------------------------------
# variant cube (the committed sweep axes)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Variant:
    paradigm: str           # experiment.PARADIGMS name
    kernel: bool            # cfg.use_agg_kernel
    featshard: bool = False  # cfg.feats_layout == "sharded"
    model: str = "graphsage"

    @property
    def name(self) -> str:
        tags = [self.paradigm, "kernel" if self.kernel else "einsum"]
        if self.featshard:
            tags.append("featshard")
        if self.model != "graphsage":
            tags.append(self.model)
        return "+".join(tags)


def sweep_variants() -> List[Variant]:
    """Every committed sweep variant: paradigm x {einsum, kernel}, plus
    the featshard layout (only reachable on fullgraph_sharded x kernel)
    and one gcn point covering the kernel's fused self-row epilogue."""
    from repro.core.experiment import PARADIGMS
    vs = [Variant(p, k) for p in PARADIGMS for k in (False, True)]
    vs.append(Variant("fullgraph_sharded", True, featshard=True))
    vs.append(Variant("fullgraph", True, model="gcn"))
    return vs


def audit_graph(n: int = 192, seed: int = 0):
    """Small synthetic graph with the presets' structure; tracing cost
    is shape-driven, so a small n keeps the full cube under CI budget
    while exercising identical code paths."""
    from repro.data.synth import make_preset
    return make_preset("arxiv-like", n=n, seed=seed)


def variant_cfg(graph, v: Variant):
    from repro.configs.base import GNNConfig
    return GNNConfig(
        name="analyze", model=v.model, n_nodes=graph.n,
        feat_dim=graph.feats.shape[1], hidden=16,
        n_classes=graph.n_classes, n_layers=2, fanout=(4, 3),
        batch_size=32, loss="ce", use_agg_kernel=v.kernel,
        agg_interpret=True, agg_b_tile=8, agg_d_tile=16, agg_k_slab=2,
        feats_layout="sharded" if v.featshard else "replicated")


def _make_source(v: Variant, cfg):
    """Thread-free twin of ``experiment.make_source``: sampled sources
    take the plain (no Prefetcher / no staging ring) path so an audit
    never spawns a worker; the traced jaxpr is identical either way
    (prefetch only changes WHERE host staging runs)."""
    from repro.core import engine as E
    b, fo = cfg.batch_size, tuple(cfg.fanout)
    kw = dict(prefetch=False, reuse_buffers=False)
    if v.paradigm == "fullgraph":
        return E.FullGraphSource()
    if v.paradigm == "fullgraph_sharded":
        return E.ShardedFullGraphSource()
    if v.paradigm == "minibatch":
        return E.SampledSource(batch_size=b, fanouts=fo, **kw)
    if v.paradigm == "minibatch_sharded":
        return E.ShardedSampledSource(batch_size=b, fanouts=fo, **kw)
    if v.paradigm == "cluster":
        return E.ClusterSource(batch_size=b)
    if v.paradigm == "importance":
        return E.ImportanceSampledSource(batch_size=b, fanouts=fo, **kw)
    raise ValueError(f"unknown paradigm {v.paradigm!r}")


def _draw_batch(src, graph):
    """One device batch without starting any source thread."""
    import jax
    from repro.core import engine as E
    rng = np.random.default_rng(0)
    if isinstance(src, E.ClusterSource):
        host, _n_valid = src._sample_union(rng, graph, src.k, ())
        return jax.device_put(host)
    if isinstance(src, E.SampledSource):
        fb = src._sample(rng, graph, src.b_request, src.fanouts)
        return src._to_device(src._host_batch(graph, fb))
    return None                              # full-graph: batch is None


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def _subjaxprs(params: Dict) -> Iterable[Tuple[Any, bool]]:
    """-> (sub-closed/open jaxpr, introduces_shard_map_scope)."""
    import jax.core as jcore
    for val in params.values():
        stack = [val]
        while stack:
            x = stack.pop()
            if isinstance(x, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                yield x
            elif isinstance(x, (tuple, list)):
                stack.extend(x)


def _iter_eqns(jaxpr, in_spmd: bool = False):
    """Depth-first (eqn, inside_shard_map) over a (Closed)Jaxpr."""
    import jax.core as jcore
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, in_spmd
        sub_spmd = in_spmd or eqn.primitive.name in _SPMD_SCOPES
        for sub in _subjaxprs(eqn.params):
            yield from _iter_eqns(sub, sub_spmd)


def _walk_hazards(closed, site: str) -> List[Finding]:
    """The per-jaxpr hazard walks shared by step/eval/inference."""
    import jax.core as jcore
    out: List[Finding] = []

    f64_counts: Dict[str, int] = {}
    f64_first: Dict[str, str] = {}
    churn_round = 0
    churn_other = 0
    stray_coll: Dict[str, int] = {}
    producers: Dict[Any, Any] = {}

    for eqn, in_spmd in _iter_eqns(closed):
        name = eqn.primitive.name
        for ov in eqn.outvars:
            dt = getattr(getattr(ov, "aval", None), "dtype", None)
            if dt is not None and str(dt) in F64:
                f64_counts[str(dt)] = f64_counts.get(str(dt), 0) + 1
                f64_first.setdefault(str(dt), name)
            producers[ov] = eqn
        if name in COLLECTIVES and not in_spmd:
            stray_coll[name] = stray_coll.get(name, 0) + 1
        if name == "convert_element_type":
            iv = eqn.invars[0]
            if isinstance(iv, jcore.Literal):
                continue
            prev = producers.get(iv)
            if prev is not None \
                    and prev.primitive.name == "convert_element_type":
                src_dt = prev.invars[0].aval.dtype \
                    if not isinstance(prev.invars[0], jcore.Literal) \
                    else prev.invars[0].aval.dtype
                if eqn.outvars[0].aval.dtype == src_dt:
                    churn_round += 1
                else:
                    churn_other += 1

    for dt, cnt in sorted(f64_counts.items()):
        out.append(Finding(
            "jaxpr", "error", site,
            f"{cnt} equation(s) produce {dt} (first: "
            f"{f64_first[dt]}) — implicit widening; the hot path is "
            f"f32/bf16 by design"))
    if churn_round:
        out.append(Finding(
            "jaxpr", "warning", site,
            f"{churn_round} convert_element_type round-trip(s) "
            "(A->B->A on the direct producer) — each one is a wasted "
            "full pass over the array"))
    if churn_other:
        out.append(Finding(
            "jaxpr", "info", site,
            f"{churn_other} chained convert_element_type pair(s) "
            "(A->B->C) that could collapse to one convert"))
    for name, cnt in sorted(stray_coll.items()):
        out.append(Finding(
            "jaxpr", "error", site,
            f"collective '{name}' appears {cnt}x OUTSIDE any shard_map "
            "scope — under plain GSPMD tracing this is a replicated "
            "all-reduce bug, not a partitioning hint"))

    # -- constants folded into the jaxpr --------------------------------
    host_bytes = dev_bytes = 0
    for c in getattr(closed, "consts", ()):
        if isinstance(c, np.ndarray):
            host_bytes += c.nbytes
            if c.nbytes >= HOST_CONST_BYTES:
                out.append(Finding(
                    "jaxpr", "error", site,
                    f"host np.ndarray constant {c.shape} {c.dtype} "
                    f"({c.nbytes} B) folded into the jaxpr — bakes an "
                    "HLO literal and defeats every identity-keyed "
                    "trace cache (closure-captured table?)"))
        elif hasattr(c, "nbytes"):       # jax.Array: deliberate consts
            dev_bytes += int(c.nbytes)
    return out


def _canonical_hash(closed) -> str:
    return hashlib.sha256(str(closed.jaxpr).encode()).hexdigest()[:16]


def _donation_findings(closed, site: str, n_batch_leaves: int
                       ) -> Tuple[List[Finding], Dict]:
    """Check that donated params/opt leaves can actually alias an
    output buffer; donated batch leaves are early-free only (tallied)."""
    out: List[Finding] = []
    eqns = closed.jaxpr.eqns
    rec = {"donated": 0, "donated_unaliasable_batch": 0}
    pjit = next((e for e in eqns if e.primitive.name == "pjit"), None)
    if pjit is None:
        return out, rec
    donated = pjit.params.get("donated_invars")
    if donated is None:
        return out, rec
    out_avals = [v.aval for v in pjit.outvars]
    pool: Dict[Tuple, int] = {}
    for a in out_avals:
        k = (a.shape, str(a.dtype))
        pool[k] = pool.get(k, 0) + 1
    invars = pjit.invars
    n_in = len(invars)
    for i, (v, d) in enumerate(zip(invars, donated)):
        if not d:
            continue
        rec["donated"] += 1
        a = v.aval
        k = (a.shape, str(a.dtype))
        is_batch = n_batch_leaves and i >= n_in - n_batch_leaves
        if pool.get(k, 0) > 0:
            pool[k] -= 1
        elif is_batch:
            # donated purely so the host batch frees early — expected
            rec["donated_unaliasable_batch"] += 1
        else:
            out.append(Finding(
                "jaxpr", "error", site,
                f"donated params/opt leaf {a.shape} {a.dtype} cannot "
                "alias any step output — donation is silently dropped "
                "and the step double-buffers this array"))
    return out, rec


# ---------------------------------------------------------------------------
# per-variant audit
# ---------------------------------------------------------------------------

def audit_variant(graph, v: Variant, plan=None
                  ) -> Tuple[List[Finding], Dict]:
    """Trace one sweep variant's cached step twice (fresh source each
    time) and run every hazard walk.  -> (findings, record)."""
    import jax
    from repro.core import engine as E
    from repro.core import gnn as G

    if plan is None:
        plan = E.TrainPlan(lr=0.1, n_iters=4, eval_every=0)
    cfg = variant_cfg(graph, v)
    site = f"variant:{v.name}"
    findings: List[Finding] = []
    rec: Dict[str, Any] = {"variant": v.name}

    def trace_once():
        src = _make_source(v, cfg).bind(graph, cfg, plan)
        try:
            consts = src.loss_consts()
            step = E._cached_step(graph, type(src), consts, cfg, plan)
            params = src.place(
                G.init_gnn(jax.random.key(0), cfg,
                           graph.feats.shape[1]))
            opt_state = src.place(plan.make_optimizer().init(params))
            batch = _draw_batch(src, graph)
            closed = jax.make_jaxpr(step)(params, opt_state, batch)
            n_batch = len(jax.tree.leaves(batch))
            return step, closed, n_batch
        finally:
            src.close()

    step1, closed1, n_batch = trace_once()
    step2, closed2, _ = trace_once()

    findings += _walk_hazards(closed1, site)
    don, drec = _donation_findings(closed1, site, n_batch)
    findings += don
    rec.update(drec)

    h1, h2 = _canonical_hash(closed1), _canonical_hash(closed2)
    rec["jaxpr_hash"] = h1
    rec["n_eqns"] = sum(1 for _ in _iter_eqns(closed1))
    rec["step_cache_hit"] = step1 is step2
    if step1 is not step2:
        findings.append(Finding(
            "jaxpr", "error", site,
            "_cached_step returned a DIFFERENT function for a fresh "
            "source bound to the same graph — the consts-identity "
            "cache key is unstable and every sweep grid point "
            "recompiles"))
    if h1 != h2:
        findings.append(Finding(
            "jaxpr", "error", site,
            f"re-trace produced a different canonical jaxpr "
            f"({h1} != {h2}) — sweep() would silently retrace/"
            "recompile this variant per grid point"))
    return findings, rec


def _audit_eval(graph, v: Variant) -> Tuple[List[Finding], Dict]:
    """Trace the module-level jitted eval (full-graph accuracy) the
    Trainer calls at eval_every; only full-graph paradigms own an ELL."""
    import jax
    from repro.core import engine as E
    from repro.core import gnn as G
    cfg = variant_cfg(graph, v)
    plan = E.TrainPlan(lr=0.1, n_iters=4, eval_every=0)
    site = f"eval:{v.name}"
    src = _make_source(v, cfg).bind(graph, cfg, plan)
    try:
        idx, w, w_self, feats, labels = src.ell
        params = src.place(
            G.init_gnn(jax.random.key(0), cfg, graph.feats.shape[1]))
        mesh = getattr(src, "_mesh", None)
        fsplan = getattr(src, "feats_plan", None)
        closed = jax.make_jaxpr(
            E._eval_acc, static_argnums=(1, 8, 9))(
                params, E._static_cfg(cfg), idx, w, w_self, feats,
                labels, src.node_split("val"), mesh, fsplan)
    finally:
        src.close()
    return _walk_hazards(closed, site), \
        {"variant": site, "jaxpr_hash": _canonical_hash(closed),
         "n_eqns": sum(1 for _ in _iter_eqns(closed))}


def _audit_inference(graph) -> Tuple[List[Finding], List[Dict]]:
    """Trace the layer-wise inference chunk function (einsum + kernel)
    — the serving tier's hot path (`core.inference`)."""
    import jax
    from repro.core import engine as E
    from repro.core import gnn as G
    from repro.core import inference as I
    findings: List[Finding] = []
    recs: List[Dict] = []
    for kernel in (False, True):
        v = Variant("fullgraph", kernel)
        cfg = variant_cfg(graph, v)
        scfg = E._static_cfg(cfg)
        params = G.init_gnn(jax.random.key(0), cfg,
                            graph.feats.shape[1])
        ell = E._device_ell(graph)
        idx, w, w_self, feats, labels = ell
        c = 64
        site = f"inference:chunk+{'kernel' if kernel else 'einsum'}"
        import jax.numpy as jnp
        rows = jnp.arange(c, dtype=jnp.int32)
        src = I._pre_source(scfg, params[0], feats)
        closed = jax.make_jaxpr(
            I._chunk_apply, static_argnums=(0, 1, 2))(
                scfg, False, None, params[0], feats, src, rows,
                idx[:c], w[:c], w_self[:c])
        findings += _walk_hazards(closed, site)
        recs.append({"variant": site,
                     "jaxpr_hash": _canonical_hash(closed),
                     "n_eqns": sum(1 for _ in _iter_eqns(closed))})
    return findings, recs


def audit_jaxprs(n: int = 192) -> Tuple[List[Finding], List[Dict]]:
    """The full jaxpr audit: every sweep variant's step, the shared
    eval function, and the inference chunk path."""
    graph = audit_graph(n=n)
    findings: List[Finding] = []
    records: List[Dict] = []
    for v in sweep_variants():
        f, r = audit_variant(graph, v)
        findings += f
        records.append(r)
    # eval: one replicated + one sharded(+featshard) trace covers the
    # (mesh, feats_plan) static dispatch of the single jitted _eval_acc
    for v in (Variant("fullgraph", True),
              Variant("fullgraph_sharded", True, featshard=True)):
        f, r = _audit_eval(graph, v)
        findings += f
        records.append(r)
    f, rs = _audit_inference(graph)
    findings += f
    records += rs
    return findings, records
