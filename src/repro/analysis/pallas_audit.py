"""Pallas kernel checker: VMEM budgets, DMA/semaphore pairing, bounds.

Three checks over the repo's kernels (``neighbor_agg`` row + tiled,
``featshard`` — which dispatches through the same tiled kernel — and
``flash_attn``):

1. **VMEM budget** — recompute the per-grid-step VMEM working set from
   the kernels' block + scratch shapes (grid-blocked operands count
   twice: Pallas double-buffers them automatically) and compare against
   the per-backend limit (~16 MB/core on TPU, pallas_guide.md
   §TPU Architecture).  The result is a machine-readable table
   (``budget_table``) that ``bench_kernel.py`` records per case and
   ``kernels/README.md`` embeds.

2. **DMA/semaphore pairing** — the tiled kernel hand-rolls a two-slot
   K-slab rotation (slab ki in slot ki % 2, next slab prefetched while
   the current one accumulates).  ``simulate_dma_pairing`` executes the
   REAL kernel body over a small concrete grid with stub ``pl`` /
   ``pltpu`` / ``jnp`` objects, so every ``pl.when`` control path runs
   as plain Python and every ``make_async_copy`` start/wait lands in an
   event log.  The checker then asserts, per semaphore and in grid
   order: no wait on an un-started copy, no second start before the
   wait (a silently overwritten in-flight DMA), a wait descriptor that
   matches its start, and zero in-flight copies at every output-tile
   boundary (so any megacore partition of the parallel axes is safe).

3. **Scalar-prefetch bounds** — every gather index that addresses an
   operand row must be in range; the simulator checks the ids the
   kernel actually dereferences, and ``check_index_bounds`` validates
   the real host-side index tables (ELL, featshard plan) an audit graph
   produces.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding

#: per-core VMEM by backend (bytes).  CPU interpret mode has no real
#: VMEM, but the budget is checked against the TPU target the kernels
#: are written for.
VMEM_LIMIT = {"tpu": 16 * 2 ** 20}
#: warn above this fraction of the limit — leaves headroom for the
#: compiler's own spills and for operands we cannot see statically
WARN_FRACTION = 0.75


# ---------------------------------------------------------------------------
# VMEM budgets (block/scratch shape formulas, mirroring the kernels)
# ---------------------------------------------------------------------------

def tiled_agg_budget(b_tile: int, d_tile: int, k_slab: int, *,
                     feat_itemsize: int = 4, out_itemsize: int = 4,
                     fuse_self: bool = False) -> Dict[str, int]:
    """Per-step VMEM bytes of ``neighbor_agg_pallas_tiled``
    (neighbor_agg.py ``_make_tiled_kernel``): the manually-DMA'd row
    double buffer + f32 accumulator scratch, plus the grid-blocked
    operands (w / optional fused-self blocks / out), each double-
    buffered by the Pallas pipeline.  feats stays in HBM (ANY) — 0."""
    parts = {
        "scratch rows[2,k_slab,b_tile,d_tile]":
            2 * k_slab * b_tile * d_tile * feat_itemsize,
        "scratch acc[b_tile,d_tile] f32": b_tile * d_tile * 4,
        "block w[b_tile,k_slab] x2": 2 * b_tile * k_slab * 4,
        "block out[b_tile,d_tile] x2": 2 * b_tile * d_tile * out_itemsize,
    }
    if fuse_self:
        parts["block w_self[b_tile,1] x2"] = 2 * b_tile * 4
        parts["block self[b_tile,d_tile] x2"] = \
            2 * b_tile * d_tile * feat_itemsize
    return parts


def row_agg_budget(d_tile: int, *, feat_itemsize: int = 4,
                   out_itemsize: int = 4) -> Dict[str, int]:
    """Per-step VMEM bytes of the seed row kernel (``_row_kernel``)."""
    return {
        "scratch acc[1,d_tile] f32": d_tile * 4,
        "block w[1,1] x2": 2 * 4,
        "block feat_row[1,d_tile] x2": 2 * d_tile * feat_itemsize,
        "block out[1,d_tile] x2": 2 * d_tile * out_itemsize,
    }


def flash_attn_budget(q_block: int, k_block: int, d: int, *,
                      itemsize: int = 4) -> Dict[str, int]:
    """Per-step VMEM bytes of ``flash_attn._kernel`` (no manual DMAs:
    q/k/v/o ride grid-blocked specs; acc/m/l are f32 scratch)."""
    return {
        "block q[1,q_block,d] x2": 2 * q_block * d * itemsize,
        "block k[1,k_block,d] x2": 2 * k_block * d * itemsize,
        "block v[1,k_block,d] x2": 2 * k_block * d * itemsize,
        "block o[1,q_block,d] x2": 2 * q_block * d * itemsize,
        "scratch acc[q_block,d] f32": q_block * d * 4,
        "scratch m[q_block] f32": q_block * 4,
        "scratch l[q_block] f32": q_block * 4,
    }


def budget_row(kernel: str, case: str, parts: Dict[str, int],
               backend: str = "tpu") -> Dict:
    total = sum(parts.values())
    limit = VMEM_LIMIT[backend]
    return {"kernel": kernel, "case": case, "backend": backend,
            "vmem_bytes": total, "vmem_limit": limit,
            "vmem_frac": round(total / limit, 5),
            "breakdown": dict(parts)}


def default_budget_table() -> List[Dict]:
    """The committed kernel cases: the GNNConfig default tiling (f32 +
    bf16 feature tables, with and without the fused self epilogue), the
    seed row kernel, and flash_attn at its default blocks."""
    rows = []
    for item, tag in ((4, "f32"), (2, "bf16")):
        for fuse in (False, True):
            case = f"b8 d128 k4 {tag}" + (" +self" if fuse else "")
            rows.append(budget_row(
                "neighbor_agg_tiled", case,
                tiled_agg_budget(8, 128, 4, feat_itemsize=item,
                                 out_itemsize=item, fuse_self=fuse)))
    rows.append(budget_row("neighbor_agg_row", "d128 f32",
                           row_agg_budget(128)))
    rows.append(budget_row("flash_attn", "q128 k128 d128 f32",
                           flash_attn_budget(128, 128, 128)))
    rows.append(budget_row("flash_attn", "q128 k128 d128 bf16",
                           flash_attn_budget(128, 128, 128, itemsize=2)))
    return rows


def audit_budgets(table: Optional[Sequence[Dict]] = None) -> List[Finding]:
    out: List[Finding] = []
    for row in (default_budget_table() if table is None else table):
        site = f"kernel:{row['kernel']}[{row['case']}]"
        if row["vmem_bytes"] > row["vmem_limit"]:
            out.append(Finding(
                "pallas", "error", site,
                f"VMEM working set {row['vmem_bytes']} B exceeds the "
                f"{row['backend']} limit {row['vmem_limit']} B "
                f"({100 * row['vmem_frac']:.1f}%)"))
        elif row["vmem_frac"] > WARN_FRACTION:
            out.append(Finding(
                "pallas", "warning", site,
                f"VMEM working set {row['vmem_bytes']} B is "
                f"{100 * row['vmem_frac']:.1f}% of the {row['backend']} "
                f"limit — no headroom for compiler spills"))
    return out


# ---------------------------------------------------------------------------
# DMA/semaphore pairing: execute the kernel body with stub pl/pltpu
# ---------------------------------------------------------------------------

class _Ref:
    """Stand-in for a pallas Ref: numpy-backed for compute refs, token-
    producing (via ``.at``) for DMA source/dest/semaphore refs."""

    def __init__(self, name: str, arr: Optional[np.ndarray] = None,
                 harness: Optional["_Harness"] = None):
        self.name = name
        self.arr = arr
        self._h = harness

    @property
    def at(self):
        return _At(self)

    @property
    def dtype(self):
        return self.arr.dtype

    @property
    def shape(self):
        return self.arr.shape

    def __array__(self, dtype=None):       # jnp/np.zeros_like support
        a = self.arr
        return a if dtype is None else a.astype(dtype)

    def __getitem__(self, key):
        return self.arr if key is Ellipsis else self.arr[key]

    def __setitem__(self, key, val):
        if key is Ellipsis:
            self.arr[...] = np.asarray(val, self.arr.dtype)
        else:
            self.arr[key] = val


class _At:
    def __init__(self, ref: _Ref):
        self._ref = ref

    def __getitem__(self, key):
        if not isinstance(key, tuple):
            key = (key,)
        h = self._ref._h
        if h is not None:
            h.on_index(self._ref.name, key)
        return (self._ref.name, tuple(_freeze(k) for k in key))


def _freeze(k):
    if isinstance(k, slice):
        return ("slice", k.start, k.stop, k.step)
    if isinstance(k, (int, np.integer)):
        return int(k)
    return k                      # ("ds", start, size) tokens pass through


class _DMA:
    def __init__(self, harness: "_Harness", src, dst, sem):
        self._h = harness
        self.desc = (src, dst, sem)

    def start(self, priority: int = 0):
        self._h.events.append(("start",) + (self.desc,) + (self._h.point,))

    def wait(self):
        self._h.events.append(("wait",) + (self.desc,) + (self._h.point,))


class _StubPL:
    def __init__(self, harness: "_Harness"):
        self._h = harness

    def program_id(self, axis: int) -> int:
        return self._h.point[axis]

    def num_programs(self, axis: int) -> int:
        return self._h.grid[axis]

    def when(self, cond):
        def deco(fn):
            if bool(cond):
                fn()
            return fn
        return deco

    def ds(self, start, size):
        return ("ds", int(start), int(size))


class _StubPLTPU:
    def __init__(self, harness: "_Harness"):
        self._h = harness

    def make_async_copy(self, src, dst, sem):
        return _DMA(self._h, src, dst, sem)


class _Harness:
    """Runs one kernel function over a concrete grid, recording DMA
    start/wait events and checking dereferenced gather ids."""

    def __init__(self, grid: Tuple[int, int, int], n_rows: int):
        self.grid = grid
        self.point = (0, 0, 0)
        self.n_rows = n_rows
        self.events: List[Tuple] = []
        self.bad_ids: List[Tuple[str, int]] = []

    def on_index(self, name: str, key: Tuple) -> None:
        # the feature-table gather: first index is the scalar-prefetched
        # neighbor id — must address a real row
        if name == "feat" and key:
            nid = key[0]
            if isinstance(nid, (int, np.integer)) \
                    and not 0 <= int(nid) < self.n_rows:
                self.bad_ids.append((name, int(nid)))


def simulate_dma_pairing(make_kernel, *, b_tile: int = 2, d_tile: int = 8,
                         k_slab: int = 2, nk: int = 3,
                         fuse_self: bool = False, n_rows: int = 16,
                         site: str = "kernel:neighbor_agg_tiled",
                         grid_bd: Tuple[int, int] = (2, 2),
                         idx: Optional[np.ndarray] = None
                         ) -> List[Finding]:
    """Execute ``make_kernel(b_tile, d_tile, k_slab, k_total,
    fuse_self)``'s kernel over a ``(grid_bd[0], grid_bd[1], nk)`` grid
    in row-major order (K innermost + sequential, matching the kernel's
    ``dimension_semantics``) and verify DMA/semaphore discipline.

    The kernel's module-level ``pl`` / ``pltpu`` / ``jnp`` names are
    swapped for stubs via ``__globals__`` for the duration — local to
    the kernel's defining module and restored in a ``finally``."""
    k_total = nk * k_slab
    gb, gd = grid_bd
    b = gb * b_tile
    grid = (gb, gd, nk)
    site = f"{site}[fuse_self={fuse_self},nk={nk}]"
    h = _Harness(grid, n_rows)
    kernel = make_kernel(b_tile, d_tile, k_slab, k_total, fuse_self)

    rng = np.random.default_rng(0)
    if idx is None:
        idx = rng.integers(0, n_rows, size=b * k_total).astype(np.int32)
    refs = dict(
        idx=_Ref("idx", np.asarray(idx).reshape(-1)),
        w=_Ref("w", np.ones((b_tile, k_slab), np.float32)),
        wself=_Ref("wself", np.ones((b_tile, 1), np.float32)),
        self_=_Ref("self", np.ones((b_tile, d_tile), np.float32)),
        feat=_Ref("feat", harness=h),
        out=_Ref("out", np.zeros((b_tile, d_tile), np.float32)),
        rows=_Ref("rows", np.zeros((2, k_slab, b_tile, d_tile),
                                   np.float32)),
        acc=_Ref("acc", np.zeros((b_tile, d_tile), np.float32)),
        sems=_Ref("sem", harness=h),
    )
    if fuse_self:
        args = (refs["idx"], refs["w"], refs["wself"], refs["self_"],
                refs["feat"], refs["out"], refs["rows"], refs["acc"],
                refs["sems"])
    else:
        args = (refs["idx"], refs["w"], refs["feat"], refs["out"],
                refs["rows"], refs["acc"], refs["sems"])

    g = kernel.__globals__
    saved = {k: g[k] for k in ("pl", "pltpu", "jnp") if k in g}
    g["pl"] = _StubPL(h)
    g["pltpu"] = _StubPLTPU(h)
    g["jnp"] = np
    findings: List[Finding] = []
    try:
        for bi in range(gb):
            for di in range(gd):
                pane_start = len(h.events)
                for ki in range(nk):
                    h.point = (bi, di, ki)
                    kernel(*args)
                findings += _check_pane(
                    h.events[pane_start:], site, pane=(bi, di))
    except Exception as e:  # a crash in the stubbed body is a finding,
        # not an analyzer error: the control path is unexecutable
        findings.append(Finding(
            "pallas", "error", site,
            f"kernel body raised under control-path simulation at grid "
            f"point {h.point}: {type(e).__name__}: {e}"))
    finally:
        g.update(saved)

    for name, nid in h.bad_ids[:4]:
        findings.append(Finding(
            "pallas", "error", site,
            f"scalar-prefetched index {nid} addresses {name} rows "
            f"outside [0, {n_rows})"))
    return findings


def _check_pane(events: Sequence[Tuple], site: str,
                pane: Tuple[int, int]) -> List[Finding]:
    """Per-semaphore alternation over one output tile's event stream:
    start -> wait (with matching descriptor), nothing left in flight at
    the pane boundary."""
    out: List[Finding] = []
    in_flight: Dict[Tuple, Tuple] = {}   # sem token -> (src, dst, point)
    for kind, (src, dst, sem), point in events:
        if kind == "start":
            if sem in in_flight:
                out.append(Finding(
                    "pallas", "error", f"{site}:sem{sem[1]}",
                    f"copy started at grid point {point} while the "
                    f"previous copy on this semaphore (started at "
                    f"{in_flight[sem][2]}) was never waited — the "
                    "in-flight DMA is silently overwritten"))
            in_flight[sem] = (src, dst, point)
        else:
            if sem not in in_flight:
                out.append(Finding(
                    "pallas", "error", f"{site}:sem{sem[1]}",
                    f"wait at grid point {point} on a semaphore with no "
                    "started copy (hangs on real hardware)"))
                continue
            s_src, s_dst, s_point = in_flight.pop(sem)
            if (s_src, s_dst) != (src, dst):
                out.append(Finding(
                    "pallas", "error", f"{site}:sem{sem[1]}",
                    f"wait descriptor at {point} does not match the "
                    f"copy started at {s_point}: started "
                    f"{s_src}->{s_dst}, waited {src}->{dst}"))
    for sem, (_, _, s_point) in sorted(in_flight.items()):
        out.append(Finding(
            "pallas", "error", f"{site}:sem{sem[1]}",
            f"copy started at {s_point} never waited within its output "
            f"tile {pane} — leaks into the next tile (and deadlocks a "
            "megacore partition at the pane boundary)"))
    return out


def audit_dma_pairing(make_kernel=None) -> List[Finding]:
    """Pairing audit over the repo's tiled kernel (or a fixture factory
    with the same signature): warm-up (nk=1), steady state + tail
    (nk=2,3), both epilogue variants.  featshard reuses this kernel via
    ``ops._tiled_call``, so its DMA discipline is covered here."""
    if make_kernel is None:
        from repro.kernels.neighbor_agg.neighbor_agg import \
            _make_tiled_kernel as make_kernel
    findings: List[Finding] = []
    for fuse in (False, True):
        for nk in (1, 2, 3):
            findings += simulate_dma_pairing(
                make_kernel, nk=nk, fuse_self=fuse)
    return findings


# ---------------------------------------------------------------------------
# Host-side index-table bounds (real data)
# ---------------------------------------------------------------------------

def check_index_bounds(idx, n_rows: int, site: str) -> List[Finding]:
    idx = np.asarray(idx)
    if idx.size == 0:
        return []
    lo, hi = int(idx.min()), int(idx.max())
    if lo < 0 or hi >= n_rows:
        return [Finding(
            "pallas", "error", site,
            f"index table range [{lo}, {hi}] escapes the operand's "
            f"[0, {n_rows}) rows — the kernel DMA would read out of "
            "bounds")]
    return []


def audit_index_tables(graph, mesh=None,
                       cache_rows: int = -1) -> List[Finding]:
    """Bounds-check the index tables the kernels actually consume for
    ``graph``: the ELL neighbor ids against the feature table, and (on
    a mesh) every featshard-plan index array against its target."""
    from repro import sharding as sh
    from repro.core.graph import to_ell
    findings: List[Finding] = []
    idx, w, _ = to_ell(graph)
    findings += check_index_bounds(idx, graph.n, "bounds:ell.idx")
    if mesh is None:
        mesh = sh.node_mesh()
    from repro.kernels.neighbor_agg.ops import build_featshard_plan
    pad = (-graph.n) % sh.nodes_shards(mesh)
    if pad:
        idx = np.pad(idx, ((0, pad), (0, 0)))
        w = np.pad(w, ((0, pad), (0, 0)))
    plan = build_featshard_plan(idx, w, graph.degrees, mesh,
                                cache_rows=cache_rows)
    n_loc = plan.n_loc
    checks = [
        ("bounds:featshard.lidx_hot", plan.lidx_hot, n_loc + plan.C_max),
        ("bounds:featshard.lidx_miss", plan.lidx_miss,
         max(plan.S * plan.M, 1)),
        ("bounds:featshard.serve_loc", plan.serve_loc, n_loc),
        ("bounds:featshard.hot_src_loc", plan.hot_src_loc, n_loc),
    ]
    for site, arr, n in checks:
        if arr is not None:
            findings += check_index_bounds(np.asarray(arr), n, site)
    return findings
