"""Concurrency lint: which threads touch which attributes.

An AST pass over the thread-crossing modules (``prefetch.py``,
``engine.py`` with its HostStagingRing usage, ``serving.py``,
``featcache.py``, ``inference.py``).  Per class it derives:

- **thread-entry methods**: targets of ``threading.Thread(target=
  self.m)`` plus methods handed to a ``Prefetcher`` as ``payload_fn=`` /
  ``sample_fn=`` (those run on the prefetch worker), closed over the
  intra-class ``self.m()`` call graph;
- per method, the ``self.<attr>`` **reads**, **writes** (assign /
  augassign / subscript store) and **mutating calls** (``.append`` /
  ``.pop`` / ``move_to_end`` / ...), each tagged with whether it sits
  inside a ``with self.<lock>:`` block;
- **discipline attributes**: ``queue.Queue`` / ``threading.Event`` /
  ``Lock`` / ``HostStagingRing`` instances assigned in ``__init__`` or
  ``bind`` — calls on these are the designated thread-safe handoff and
  are never flagged (rebinding them still counts as a write).

Findings:

- ``error`` — an attribute written (unlocked, non-discipline) from BOTH
  a worker-side and a main-side method: a data race unless some
  external protocol orders it.  This is the gate; intentional cases go
  in ``allowlist.toml`` with a reason.
- ``warning`` — a worker-side unlocked write to an attribute that a
  main-side method also MUTATES through method calls (list/dict
  mutation races that assignment-tracking alone would miss).
- ``info`` — single-writer, cross-thread reader without a lock: the
  deliberate lock-free handoffs (``Prefetcher._err`` is written before
  the sentinel ``put`` whose matching ``get`` orders the read).
  Report-only, so the committed allowlist stays near-empty.

``__init__`` / ``bind`` writes are pre-thread setup and exempt.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding

#: method names that mutate their receiver in place
MUTATORS = frozenset({
    "append", "extend", "insert", "remove", "pop", "popitem", "clear",
    "add", "discard", "update", "setdefault", "move_to_end", "sort",
    "reverse", "appendleft", "popleft", "fill",
})

#: constructor names whose instances ARE the designated cross-thread
#: discipline (their methods synchronize internally)
DISCIPLINE_TYPES = frozenset({
    "Queue", "LifoQueue", "PriorityQueue", "SimpleQueue", "Event",
    "Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore",
    "Barrier", "HostStagingRing",
})

#: methods whose writes happen before any worker thread exists
SETUP_METHODS = frozenset({"__init__", "bind"})

#: keyword names that hand a bound method to the Prefetcher worker
WORKER_CALLBACK_KWARGS = frozenset({"payload_fn", "sample_fn"})

#: the thread-crossing modules this audit covers (relative to the
#: ``repro`` package root)
AUDITED_MODULES = (
    "core/prefetch.py",
    "core/engine.py",
    "core/serving.py",
    "core/featcache.py",
    "core/inference.py",
    "core/embedding_store.py",
)


class _Access:
    __slots__ = ("kind", "attr", "method", "locked", "line")

    def __init__(self, kind: str, attr: str, method: str, locked: bool,
                 line: int):
        self.kind = kind          # read | write | mutcall
        self.attr = attr
        self.method = method
        self.locked = locked
        self.line = line


class _MethodVisitor(ast.NodeVisitor):
    """Collect self.<attr> accesses in one method, tracking ``with
    self.<attr>:`` nesting as lock protection."""

    def __init__(self, method: str, self_name: str = "self"):
        self.method = method
        self.self_name = self_name
        self.accesses: List[_Access] = []
        self.calls: Set[str] = set()          # self.m() intra-class calls
        self.callbacks: Set[str] = set()      # self.m passed as worker cb
        self.thread_targets: Set[str] = set()  # Thread(target=self.m)
        self._lock_depth = 0

    # -- helpers -------------------------------------------------------
    def _self_attr(self, node) -> Optional[str]:
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == self.self_name:
            return node.attr
        return None

    def _rec(self, kind: str, attr: str, line: int) -> None:
        self.accesses.append(_Access(kind, attr, self.method,
                                     self._lock_depth > 0, line))

    # -- visitors ------------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        held = any(self._self_attr(item.context_expr) is not None
                   for item in node.items)
        for item in node.items:
            self.visit(item.context_expr)
        if held:
            self._lock_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if held:
            self._lock_depth -= 1

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = self._self_attr(node)
        if attr is not None:
            if isinstance(node.ctx, (ast.Store, ast.AugStore)
                          if hasattr(ast, "AugStore") else ast.Store):
                self._rec("write", attr, node.lineno)
            elif isinstance(node.ctx, ast.Del):
                self._rec("write", attr, node.lineno)
            else:
                self._rec("read", attr, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        attr = self._self_attr(node.target)
        if attr is not None:
            self._rec("write", attr, node.lineno)
        elif isinstance(node.target, ast.Subscript):
            base = self._self_attr(node.target.value)
            if base is not None:
                self._rec("mutcall", base, node.lineno)
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if isinstance(node.ctx, (ast.Store, ast.Del)):
            base = self._self_attr(node.value)
            if base is not None:       # self.x[k] = v mutates x in place
                self._rec("mutcall", base, node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        # self.m(...) — intra-class call edge
        if isinstance(func, ast.Attribute):
            recv = func.value
            m = self._self_attr(recv)
            if isinstance(recv, ast.Name) and recv.id == self.self_name:
                self.calls.add(func.attr)
            elif m is not None and func.attr in MUTATORS:
                self._rec("mutcall", m, node.lineno)
        # Thread(target=self.m) / Prefetcher(payload_fn=self.m, ...)
        for kw in node.keywords:
            tgt = self._self_attr(kw.value)
            if tgt is None:
                continue
            if kw.arg == "target":
                self.thread_targets.add(tgt)
            elif kw.arg in WORKER_CALLBACK_KWARGS:
                self.callbacks.add(tgt)
        self.generic_visit(node)


def _call_name(node) -> Optional[str]:
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Name):
            return f.id
        if isinstance(f, ast.Attribute):
            return f.attr
    return None


class _ClassInfo:
    def __init__(self, node: ast.ClassDef, modname: str):
        self.name = node.name
        self.modname = modname
        self.methods: Dict[str, _MethodVisitor] = {}
        self.discipline: Set[str] = set()
        for item in node.body:
            if not isinstance(item, ast.FunctionDef):
                continue
            args = item.args.posonlyargs + item.args.args
            self_name = args[0].arg if args else "self"
            mv = _MethodVisitor(item.name, self_name)
            for stmt in item.body:
                mv.visit(stmt)
            self.methods[item.name] = mv
            if item.name in SETUP_METHODS:
                for stmt in ast.walk(item):
                    if isinstance(stmt, ast.Assign):
                        cname = _call_name(stmt.value)
                        if cname in DISCIPLINE_TYPES:
                            for tgt in stmt.targets:
                                a = mv._self_attr(tgt)
                                if a is not None:
                                    self.discipline.add(a)

    # -- thread-side closure -------------------------------------------
    def entries(self) -> Set[str]:
        out: Set[str] = set()
        for mv in self.methods.values():
            out |= mv.thread_targets & self.methods.keys()
            out |= mv.callbacks & self.methods.keys()
        return out

    def worker_side(self) -> Set[str]:
        seen = set()
        todo = list(self.entries())
        while todo:
            m = todo.pop()
            if m in seen or m not in self.methods:
                continue
            seen.add(m)
            todo += [c for c in self.methods[m].calls if c not in seen]
        return seen

    def audit(self) -> List[Finding]:
        worker = self.worker_side()
        if not worker:
            return []
        site_base = f"{self.modname}.{self.name}"
        # attr -> {(side, kind, locked): [methods]}
        per_attr: Dict[str, Dict[Tuple[str, str, bool], Set[str]]] = {}
        for mname, mv in self.methods.items():
            if mname in SETUP_METHODS:
                continue
            sides = set()
            if mname in worker:
                sides.add("worker")
                # a worker-side method also invoked inline by a main-side
                # method (the non-prefetch path) runs on BOTH threads
                if self._also_called_from_main(mname, worker):
                    sides.add("main")
            else:
                sides.add("main")
            for acc in mv.accesses:
                d = per_attr.setdefault(acc.attr, {})
                for side in sides:
                    d.setdefault((side, acc.kind, acc.locked),
                                 set()).add(mname)
        findings: List[Finding] = []
        for attr, d in sorted(per_attr.items()):
            if attr in self.discipline:
                # calls on the discipline object are the handoff; only a
                # REBIND from two sides would race, fold into writes
                w_w = d.get(("worker", "write", False), set())
                m_w = d.get(("main", "write", False), set())
            else:
                w_w = (d.get(("worker", "write", False), set())
                       | d.get(("worker", "mutcall", False), set()))
                m_w = (d.get(("main", "write", False), set())
                       | d.get(("main", "mutcall", False), set()))
            site = f"{site_base}.{attr}"
            if w_w and m_w:
                findings.append(Finding(
                    "thread", "error", site,
                    f"written without a lock from the worker side "
                    f"({sorted(w_w)}) AND the main side ({sorted(m_w)}) "
                    "— no queue/ring/lock discipline orders these "
                    "writes"))
                continue
            if attr in self.discipline:
                continue
            m_mut = d.get(("main", "mutcall", False), set())
            w_mut = d.get(("worker", "mutcall", False), set())
            if (w_w and m_mut) or (m_w and w_mut):
                findings.append(Finding(
                    "thread", "warning", site,
                    f"rebound on one thread ({sorted(w_w or m_w)}) while "
                    f"mutated in place on the other "
                    f"({sorted(m_mut or w_mut)})"))
                continue
            readers = (d.get(("main", "read", False), set())
                       if w_w else d.get(("worker", "read", False), set())
                       if m_w else set())
            writers = w_w or m_w
            readers -= writers
            if writers and readers:
                findings.append(Finding(
                    "thread", "info", site,
                    f"lock-free handoff: written by {sorted(writers)} on "
                    f"one thread, read by {sorted(readers)} on the other "
                    "— safe only if an existing queue put/get or join "
                    "orders the access"))
        return findings

    def _also_called_from_main(self, mname: str, worker: Set[str]) -> bool:
        """A worker-side method also invoked by a main-side method runs
        on BOTH threads (e.g. the non-prefetch path calling the staging
        callback inline)."""
        if mname not in worker:
            return False
        return any(mname in mv.calls
                   for other, mv in self.methods.items()
                   if other not in worker and other not in SETUP_METHODS)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def analyze_source(src: str, modname: str) -> List[Finding]:
    tree = ast.parse(src)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            findings += _ClassInfo(node, modname).audit()
    return findings


def analyze_file(path: str, modname: Optional[str] = None
                 ) -> List[Finding]:
    with open(path) as f:
        src = f.read()
    if modname is None:
        modname = os.path.splitext(os.path.basename(path))[0]
    return analyze_source(src, modname)


def audit_threads() -> List[Finding]:
    """The repo sweep over ``AUDITED_MODULES``."""
    import repro
    # repro is a namespace package (no __init__.py): __file__ is None
    root = list(repro.__path__)[0]
    findings: List[Finding] = []
    for rel in AUDITED_MODULES:
        path = os.path.join(root, rel)
        modname = "repro." + rel[:-3].replace("/", ".")
        findings += analyze_file(path, modname)
    return findings
