"""Deliberately-broken inputs for the ``repro.analysis`` checkers.

Each fixture seeds exactly one hazard class and is used from two
places: ``scripts/analyze.py --fixture <name>`` (must exit nonzero —
the CI self-test that the gate actually gates) and
``tests/test_analysis.py`` (asserts the specific finding).  Keeping
them importable from ``repro.analysis`` rather than inlined in the
test file matters for the DMA fixture: ``simulate_dma_pairing`` swaps
the kernel's module-level ``pl`` / ``pltpu`` / ``jnp`` for stubs via
``kernel.__globals__``, so the broken kernel must resolve those names
as globals of its defining module (a closure over the real modules
would dodge the patch and crash on ``pl.program_id`` outside a trace).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# pallas: unmatched DMA wait
# ---------------------------------------------------------------------------

def make_unmatched_wait_kernel(b_tile: int, d_tile: int, k_slab: int,
                               k_total: int, fuse_self: bool):
    """Same two-slot K-slab rotation as the real ``_make_tiled_kernel``
    but the wait is fenced to ``ki + 1 < nk``: the LAST slab's copies
    are consumed un-waited and leak past the output-tile boundary.
    ``simulate_dma_pairing`` must flag every leaked copy."""

    def kernel(idx_ref, w_ref, *refs):
        if fuse_self:
            wself_ref, self_ref, feat_ref, out_ref, rows_ref, acc_ref, \
                sems = refs
        else:
            feat_ref, out_ref, rows_ref, acc_ref, sems = refs
        bi = pl.program_id(0)
        di = pl.program_id(1)
        ki = pl.program_id(2)
        nk = pl.num_programs(2)

        def slab_copies(slab, slot):
            copies = []
            for j in range(k_slab):
                for i in range(b_tile):
                    nid = idx_ref[(bi * b_tile + i) * k_total
                                  + slab * k_slab + j]
                    copies.append(pltpu.make_async_copy(
                        feat_ref.at[nid, pl.ds(di * d_tile, d_tile)],
                        rows_ref.at[slot, j, i, :],
                        sems.at[slot, j, i]))
            return copies

        @pl.when(ki == 0)
        def _init():
            for c in slab_copies(0, 0):
                c.start()
            acc_ref[...] = jnp.zeros_like(acc_ref)

        @pl.when(ki + 1 < nk)
        def _prefetch_next():
            for c in slab_copies(ki + 1, (ki + 1) % 2):
                c.start()

        # BUG under test: should be unconditional — the tail slab
        # (ki == nk - 1) is never waited.
        @pl.when(ki + 1 < nk)
        def _wait_current():
            for c in slab_copies(ki, ki % 2):
                c.wait()

        w_blk = w_ref[...].astype(jnp.float32)
        slot = ki % 2
        for j in range(k_slab):
            acc_ref[...] += w_blk[:, j:j + 1] \
                * rows_ref[slot, j].astype(jnp.float32)

        @pl.when(ki == nk - 1)
        def _flush():
            out_ref[...] = acc_ref[...].astype(out_ref.dtype)

    return kernel


# ---------------------------------------------------------------------------
# jaxpr: closure-captured host constant / f64 widening
# ---------------------------------------------------------------------------

#: bytes of the captured table — comfortably past HOST_CONST_BYTES
CAPTURED_TABLE_ELEMS = 4096


def make_constant_capture_fn():
    """-> (fn, example_arg): ``fn`` closes over a 16 KiB host
    ``np.ndarray`` that tracing folds into ``closed.consts`` — the
    jaxpr checker must report the baked HLO literal."""
    table = np.arange(CAPTURED_TABLE_ELEMS, dtype=np.float32)

    def step(x):
        return x * 2.0 + table

    return step, jnp.ones(CAPTURED_TABLE_ELEMS, jnp.float32)


def make_f64_fn():
    """-> (fn, example_arg): widens to float64.  Trace under
    ``jax.experimental.enable_x64(True)`` so the widening survives into
    the jaxpr instead of being silently clamped to f32."""

    def f(x):
        return jnp.asarray(x, jnp.float64) * 2.0

    return f, np.ones(8, np.float32)


# ---------------------------------------------------------------------------
# thread: shared attribute written from both sides
# ---------------------------------------------------------------------------

#: a worker thread and the main thread both rebind ``self.count``
#: without any lock/queue discipline — the thread checker must emit an
#: error for ``fixture_mod.LossyCounter.count``
BROKEN_THREAD_SRC = '''\
import threading


class LossyCounter:
    def __init__(self):
        self._thread = None
        self.count = 0

    def start(self):
        self._thread = threading.Thread(target=self._run)
        self._thread.start()

    def _run(self):
        while True:
            self.count = self.count + 1

    def reset(self):
        self.count = 0
'''


# ---------------------------------------------------------------------------
# runners — shared by scripts/analyze.py --fixture and the tests
# ---------------------------------------------------------------------------

def run_fixture(name: str):
    """Run one seeded-broken fixture through its checker.
    -> list[Finding]; the caller asserts/gates on non-emptiness."""
    from repro.analysis import pallas_audit, thread_audit
    from repro.analysis.jaxpr_audit import _walk_hazards

    if name == "dma":
        return pallas_audit.simulate_dma_pairing(
            make_unmatched_wait_kernel, nk=3,
            site="fixture:unmatched_wait")
    if name == "constant":
        import jax
        fn, arg = make_constant_capture_fn()
        return _walk_hazards(jax.make_jaxpr(fn)(arg), "fixture:constant")
    if name == "f64":
        import jax
        import jax.experimental
        fn, arg = make_f64_fn()
        with jax.experimental.enable_x64(True):
            closed = jax.make_jaxpr(fn)(arg)
        return _walk_hazards(closed, "fixture:f64")
    if name == "thread":
        return thread_audit.analyze_source(BROKEN_THREAD_SRC,
                                           "fixture_mod")
    raise ValueError(f"unknown fixture {name!r} "
                     "(expected dma|constant|f64|thread)")


FIXTURES = ("dma", "constant", "f64", "thread")
