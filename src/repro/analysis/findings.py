"""Finding type + allowlist + report plumbing for ``repro.analysis``.

Every checker (jaxpr_audit / pallas_audit / thread_audit) emits a flat
list of ``Finding`` records; ``scripts/analyze.py`` renders them as a
CLI report / JSON blob and exits nonzero when any *gating* finding
(severity "error" or "warning") survives the allowlist.  "info"
findings are report-only: deliberate lock-free handoffs and
known-unaliasable donations show up in the log without blocking CI.

The allowlist (``analysis/allowlist.toml``) is the explicit escape
hatch for findings that are intentional.  Entries match on
``checker`` + ``site`` prefix and MUST carry a ``reason`` — an entry
without one is itself reported as an error, so the file cannot silently
grow.  Acceptance for ISSUE 9 keeps it at <= 3 entries.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Sequence, Tuple

SEVERITIES = ("error", "warning", "info")

#: severities that make ``scripts/analyze.py`` exit nonzero
GATING = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One checker hit.

    ``site`` is a stable dotted/paths-ish locator ("module.Class.attr",
    "kernel:neighbor_agg_tiled[nk=3]", "variant:cluster+kernel") used
    both for human grep-ability and for allowlist prefix matching.
    """
    checker: str         # jaxpr | pallas | thread
    severity: str        # error | warning | info
    site: str
    detail: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r} "
                             f"(expected one of {SEVERITIES})")

    def as_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return (f"[{self.checker}:{self.severity}] {self.site}\n"
                f"    {self.detail}")


# ---------------------------------------------------------------------------
# Allowlist (TOML subset — python 3.10 has no tomllib and the container
# rule is no new deps, so parse the narrow shape we actually write:
# [[allow]] tables of string keys)
# ---------------------------------------------------------------------------

def parse_allowlist(text: str) -> List[Dict[str, str]]:
    """Parse ``[[allow]]`` tables of ``key = "value"`` string pairs.

    Comments (whole-line or trailing ``#`` outside quotes) and blank
    lines are skipped.  Anything else is a hard error — the allowlist
    is a security-relevant config, not a place for silent parse drift.
    """
    entries: List[Dict[str, str]] = []
    cur: Dict[str, str] | None = None
    for ln, raw in enumerate(text.splitlines(), 1):
        line = _strip_comment(raw).strip()
        if not line:
            continue
        if line == "[[allow]]":
            cur = {}
            entries.append(cur)
            continue
        if "=" in line and cur is not None:
            key, _, val = line.partition("=")
            key, val = key.strip(), val.strip()
            if not (len(val) >= 2 and val[0] == val[-1] == '"'):
                raise ValueError(
                    f"allowlist.toml:{ln}: value for {key!r} must be a "
                    f"double-quoted string, got {val!r}")
            cur[key] = val[1:-1]
            continue
        raise ValueError(f"allowlist.toml:{ln}: unparseable line {raw!r} "
                         "(only [[allow]] tables of string keys)")
    return entries


def _strip_comment(line: str) -> str:
    out = []
    in_str = False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out)


def load_allowlist(path) -> Tuple[List[Dict[str, str]], List[Finding]]:
    """-> (entries, findings-about-the-allowlist-itself)."""
    import os
    bad: List[Finding] = []
    if not os.path.exists(path):
        return [], bad
    with open(path) as f:
        entries = parse_allowlist(f.read())
    for e in entries:
        missing = [k for k in ("checker", "site", "reason") if not e.get(k)]
        if missing:
            bad.append(Finding(
                "allowlist", "error", f"allowlist:{e.get('site', '?')}",
                f"entry is missing required keys {missing} — every "
                "allowlist entry must say what it matches and WHY"))
    return entries, bad


def apply_allowlist(findings: Sequence[Finding],
                    entries: Sequence[Dict[str, str]]
                    ) -> Tuple[List[Finding], List[Finding]]:
    """-> (kept, suppressed).  An entry suppresses findings of its
    ``checker`` whose site starts with its ``site`` string."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        if any(e.get("checker") == f.checker
               and f.site.startswith(e.get("site", "\0"))
               for e in entries):
            suppressed.append(f)
        else:
            kept.append(f)
    return kept, suppressed


# ---------------------------------------------------------------------------
# Report rendering
# ---------------------------------------------------------------------------

def gating(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity in GATING]


def render_report(findings: Sequence[Finding],
                  suppressed: Sequence[Finding] = (),
                  extra: Dict | None = None) -> str:
    order = {s: i for i, s in enumerate(SEVERITIES)}
    lines: List[str] = []
    for f in sorted(findings, key=lambda f: (order[f.severity], f.checker,
                                             f.site)):
        lines.append(str(f))
    counts = {s: sum(1 for f in findings if f.severity == s)
              for s in SEVERITIES}
    lines.append(f"-- {counts['error']} error(s), "
                 f"{counts['warning']} warning(s), "
                 f"{counts['info']} info, "
                 f"{len(suppressed)} allowlisted")
    if extra:
        for k, v in extra.items():
            lines.append(f"-- {k}: {v}")
    return "\n".join(lines)


def as_json(findings: Sequence[Finding],
            suppressed: Sequence[Finding] = (),
            extra: Dict | None = None) -> str:
    return json.dumps({
        "findings": [f.as_dict() for f in findings],
        "suppressed": [f.as_dict() for f in suppressed],
        **(extra or {}),
    }, indent=1, sort_keys=True)
