.PHONY: check test bench-quick bench-engine bench-engine-baseline \
	sweep-smoke serve-smoke chaos

check:
	bash scripts/ci.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench-quick:
	PYTHONPATH=src:. python benchmarks/bench_kernel.py --quick
	PYTHONPATH=src:. python benchmarks/bench_sampler.py --quick

bench-engine:
	PYTHONPATH=src:. python benchmarks/bench_engine.py --smoke --check \
	--devices 4

bench-engine-baseline:
	PYTHONPATH=src:. python benchmarks/bench_engine.py --smoke --devices 4

serve-smoke:
	PYTHONPATH=src python -m repro.launch.serve --smoke --nodes 300 \
	--chunk 64 --queries 32 --updates 4
	PYTHONPATH=src python -m repro.launch.serve --smoke --kernel \
	--nodes 200 --chunk 64 --queries 16 --updates 4

chaos:
	PYTHONPATH=src python -m pytest -x -q tests/test_chaos.py \
	tests/test_checkpoint.py tests/test_resume.py
	PYTHONPATH=src python scripts/sweep_resume_smoke.py

sweep-smoke:
	PYTHONPATH=src:. python -c "from repro.core.experiment import main; \
	main(['--preset', 'arxiv-like', '--n', '300', '--iters', '3', \
	'--bs', '16', '32', '--fanout', '3', '--layers', '1', \
	'--out', 'ci_sweep_smoke']); \
	main(['--preset', 'arxiv-like', '--n', '300', '--iters', '3', \
	'--bs', '32', '--fanout', '3', '--layers', '1', \
	'--sources', 'cluster', 'importance', 'minibatch_sharded', \
	'--out', 'ci_sweep_smoke_sources']); \
	main(['--preset', 'arxiv-like', '--n', '300', '--iters', '3', \
	'--bs', '32', '--fanout', '3', '--layers', '1', '--kernel', \
	'--sources', 'minibatch_sharded', \
	'--out', 'ci_sweep_smoke_sharded_kernel'])"
