.PHONY: check test bench-quick

check:
	bash scripts/ci.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench-quick:
	PYTHONPATH=src:. python benchmarks/bench_kernel.py --quick
	PYTHONPATH=src:. python benchmarks/bench_sampler.py --quick
