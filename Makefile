.PHONY: check test analyze analyze-fixtures bench-quick bench-engine \
	bench-engine-baseline bench-promote sweep-smoke serve-smoke chaos

check:
	bash scripts/ci.sh

test:
	PYTHONPATH=src python -m pytest -x -q

# static audit (jaxpr / pallas / thread checkers); nonzero iff a gating
# finding survives analysis/allowlist.toml.  Traced jaxprs are cached
# by source digest, so an unchanged tree re-checks in seconds.
analyze:
	python scripts/analyze.py --json

# self-test: each seeded-broken fixture MUST make the gate fire
analyze-fixtures:
	! python scripts/analyze.py --fixture dma
	! python scripts/analyze.py --fixture constant
	! python scripts/analyze.py --fixture f64
	! python scripts/analyze.py --fixture thread

bench-quick:
	PYTHONPATH=src:. python benchmarks/bench_kernel.py --quick
	PYTHONPATH=src:. python benchmarks/bench_sampler.py --quick

bench-engine:
	PYTHONPATH=src:. python benchmarks/bench_engine.py --smoke --check \
	--devices 4

bench-engine-baseline:
	PYTHONPATH=src:. python benchmarks/bench_engine.py --smoke --devices 4

# refresh BENCH_engine.json only if the regression gate passes (atomic
# tmp+rename; a red gate leaves the committed baseline untouched)
bench-promote:
	PYTHONPATH=src:. python benchmarks/bench_engine.py --smoke --check \
	--promote --devices 4

serve-smoke:
	PYTHONPATH=src python -m repro.launch.serve --smoke --nodes 300 \
	--chunk 64 --queries 32 --updates 4
	PYTHONPATH=src python -m repro.launch.serve --smoke --kernel \
	--nodes 200 --chunk 64 --queries 16 --updates 4

chaos:
	PYTHONPATH=src python -m pytest -x -q tests/test_chaos.py \
	tests/test_checkpoint.py tests/test_resume.py \
	tests/test_serving_chaos.py
	PYTHONPATH=src python scripts/sweep_resume_smoke.py

sweep-smoke:
	PYTHONPATH=src:. python -c "from repro.core.experiment import main; \
	main(['--preset', 'arxiv-like', '--n', '300', '--iters', '3', \
	'--bs', '16', '32', '--fanout', '3', '--layers', '1', \
	'--out', 'ci_sweep_smoke']); \
	main(['--preset', 'arxiv-like', '--n', '300', '--iters', '3', \
	'--bs', '32', '--fanout', '3', '--layers', '1', \
	'--sources', 'cluster', 'importance', 'minibatch_sharded', \
	'--out', 'ci_sweep_smoke_sources']); \
	main(['--preset', 'arxiv-like', '--n', '300', '--iters', '3', \
	'--bs', '32', '--fanout', '3', '--layers', '1', '--kernel', \
	'--sources', 'minibatch_sharded', \
	'--out', 'ci_sweep_smoke_sharded_kernel'])"
	# 4-virtual-device featshard point: NODES-sharded feature table +
	# hot cache through the full-graph kernel path (the XLA flag must be
	# set before jax initializes, hence the separate process)
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
	JAX_PLATFORMS=cpu PYTHONPATH=src:. python -c \
	"from repro.core.experiment import main; \
	main(['--preset', 'arxiv-like', '--n', '300', '--iters', '3', \
	'--bs', '32', '--fanout', '3', '--layers', '1', '--kernel', \
	'--feats-layout', 'sharded', '--sources', 'fullgraph_sharded', \
	'--out', 'ci_sweep_smoke_featshard'])"
